//! Domain example: locating the Ising phase transition with minibatched
//! sampling.
//!
//! Sweeps the inverse temperature β of a fully connected RBF Ising model
//! and tracks the absolute magnetization |m| = |Σ s_i| / n estimated from
//! MGPMH samples. Below the critical coupling the chain hovers near
//! m ≈ 0; above it the spins align and |m| → 1. The same physics the
//! paper's §B model exhibits, measured entirely with the minibatched
//! sampler — a workload where vanilla Gibbs would spend O(DΔ) per step.
//!
//! Run with: `cargo run --release --example ising_phase`

use mbgibbs::graph::models;
use mbgibbs::rng::Pcg64;
use mbgibbs::samplers::{MgpmhSampler, Sampler};

fn magnetization(state: &[u16]) -> f64 {
    let up = state.iter().filter(|&&v| v == 1).count() as f64;
    let n = state.len() as f64;
    (2.0 * up - n).abs() / n
}

fn main() {
    let grid_n = 12; // n = 144: fast but still clearly shows the transition
    let gamma = 1.5;
    println!("RBF Ising {grid_n}×{grid_n}, γ = {gamma}: |magnetization| vs β\n");
    println!("{:>6} {:>10} {:>10} {:>12} {:>12}", "beta", "L", "psi", "<|m|>", "acc rate");

    for &beta in &[0.2, 0.6, 1.0, 1.4, 1.8, 2.4, 3.0] {
        let model = models::ising_rbf(grid_n, beta, gamma);
        let stats = model.graph.stats().clone();
        let lambda = (stats.l * stats.l).max(1.0);
        let mut sampler = MgpmhSampler::new(&model.graph, lambda);
        let mut rng = Pcg64::seeded(7);
        let n = model.graph.n();
        let mut state = vec![0u16; n];

        let burnin = 150_000u64;
        let measure = 150_000u64;
        for _ in 0..burnin {
            sampler.step(&mut state, &mut rng);
        }
        let mut acc = 0.0;
        let mut count = 0u64;
        for it in 0..measure {
            sampler.step(&mut state, &mut rng);
            if it % 50 == 0 {
                acc += magnetization(&state);
                count += 1;
            }
        }
        println!(
            "{:>6.1} {:>10.3} {:>10.1} {:>12.4} {:>12.3}",
            beta,
            stats.l,
            stats.psi,
            acc / count as f64,
            sampler.acceptance_rate()
        );
    }
    println!(
        "\nExpect <|m|> near 0 at small β (disordered) rising toward 1 at\n\
         large β (ordered) — the ferromagnetic phase transition."
    );
}
