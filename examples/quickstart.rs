//! Quickstart: sample the paper's Potts model with vanilla Gibbs and the
//! minibatched samplers, and compare work per iteration.
//!
//! Run with: `cargo run --release --example quickstart`

use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::coordinator::{run_chains, RunOptions, RunSpec};
use mbgibbs::graph::models;
use mbgibbs::samplers::EnergyPath;

fn main() {
    // The paper's §B Potts model: 20×20 fully connected grid, D = 10,
    // β = 4.6, Gaussian-RBF interactions (L = 5.09, Ψ = 957.1).
    let model = models::paper_potts();
    let stats = model.graph.stats().clone();
    println!(
        "Potts model: n = {}, D = {}, Δ = {}, L = {:.2}, Ψ = {:.1}",
        model.graph.n(),
        model.graph.domain_size(),
        stats.delta,
        stats.l,
        stats.psi
    );
    println!(
        "L² = {:.1} ≪ Δ = {} — the regime where MGPMH wins\n",
        stats.l * stats.l,
        stats.delta
    );

    let iters = 200_000;
    let lineup = [
        SamplerSpec::Gibbs(EnergyPath::Generic),
        SamplerSpec::Local {
            batch: stats.delta / 4,
        },
        SamplerSpec::Mgpmh {
            lambda: stats.l * stats.l,
        },
    ];
    println!(
        "{:<36} {:>12} {:>14} {:>12}",
        "sampler", "evals/iter", "steps/sec", "l2 error"
    );
    for spec in lineup {
        let run = RunSpec::builder(spec)
            .iters(iters)
            .record_every(iters / 10)
            .build()
            .expect("valid run spec");
        let report = run_chains(&model.graph, &run, &RunOptions::default());
        println!(
            "{:<36} {:>12.1} {:>14.0} {:>12.5}",
            spec.label(&model.graph),
            report.evals_per_iter,
            report.steps_per_sec,
            report.mean_final_error()
        );
    }
    println!(
        "\nAll samplers share the same stationary marginals (uniform by\n\
         symmetry); MGPMH does ~O(DL² + Δ) work per step vs Gibbs's O(DΔ)."
    );
}
