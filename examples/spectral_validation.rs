//! Numeric validation of the paper's spectral-gap theorems on enumerable
//! models.
//!
//! Builds exact transition matrices for vanilla Gibbs and MGPMH over tiny
//! random graphs, verifies reversibility and stationarity (Theorem 3), and
//! checks the Theorem-4 bound γ̄ ≥ exp(−L²/λ)·γ across a λ sweep.
//!
//! Run with: `cargo run --release --example spectral_validation`

use mbgibbs::analysis::{
    exact_distribution, gibbs_transition_matrix, mgpmh_transition_matrix,
    spectral_gap_reversible, transition,
};
use mbgibbs::graph::models;

fn main() {
    println!("Theorem 3/4 validation on enumerable models\n");
    println!(
        "{:>6} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "seed", "lambda", "gamma", "gamma_mb", "ratio", "bound", "holds"
    );
    let mut worst_margin = f64::INFINITY;
    for seed in 0..6u64 {
        let g = models::tiny_random(3, 2, 0.7, 200 + seed);
        let s = g.stats().clone();
        let pi = exact_distribution(&g);
        let tg = gibbs_transition_matrix(&g);
        let rev = transition::reversibility_violation(&tg, &pi);
        assert!(rev < 1e-12, "gibbs must be reversible (got {rev})");
        let gamma = spectral_gap_reversible(&tg, &pi);

        for &scale in &[0.5f64, 1.0, 2.0] {
            let lambda = (s.l * s.l * scale).max(0.25);
            let tm = mgpmh_transition_matrix(&g, lambda);
            // Theorem 3: reversible with stationary distribution π.
            let rev = transition::reversibility_violation(&tm, &pi);
            let sta = transition::stationarity_violation(&tm, &pi);
            assert!(rev < 1e-8 && sta < 1e-8, "Theorem 3 violated: {rev} {sta}");
            let gamma_mb = spectral_gap_reversible(&tm, &pi);
            // Theorem 4: γ̄ ≥ exp(−L²/λ)·γ — in the paper's recommended
            // regime λ = Θ(L²), where the bound is loose enough to hold.
            let bound = (-s.l * s.l / lambda).exp();
            let ratio = gamma_mb / gamma;
            let holds = ratio >= bound - 1e-9;
            worst_margin = worst_margin.min(ratio - bound);
            println!(
                "{:>6} {:>8.2} {:>10.5} {:>10.5} {:>10.4} {:>10.4} {:>8}",
                200 + seed,
                lambda,
                gamma,
                gamma_mb,
                ratio,
                bound,
                holds
            );
            assert!(holds, "Theorem 4 bound violated in the λ = Θ(L²) regime");
        }
    }
    println!(
        "\nAll chains reversible & stationary wrt π (Thm 3); spectral-gap\n\
         ratio exceeded the exp(−L²/λ) bound at every λ = Θ(L²) setting\n\
         (Thm 4). Worst margin above bound: {worst_margin:.4}\n"
    );

    // --- Large-λ regime: the literal Theorem-4 bound breaks down. ---
    // The convergence of γ̄/γ to 1 is empirically Θ(L/√λ), slower than the
    // bound's 1 − L²/λ; see EXPERIMENTS.md §Discrepancies for the proof
    // step this traces to. Report, don't assert.
    println!("large-λ regime (discrepancy — see EXPERIMENTS.md):");
    let g = models::tiny_random(3, 2, 0.9, 77);
    let s = g.stats().clone();
    let pi = exact_distribution(&g);
    let gamma = spectral_gap_reversible(&gibbs_transition_matrix(&g), &pi);
    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>14}",
        "lambda", "ratio", "bound", "paper holds", "deficit·√λ/L"
    );
    for &lambda in &[10.0f64, 40.0, 160.0, 640.0] {
        let gm = spectral_gap_reversible(&mgpmh_transition_matrix(&g, lambda), &pi);
        let ratio = gm / gamma;
        let bound = (-s.l * s.l / lambda).exp();
        println!(
            "{:>8.0} {:>10.5} {:>10.5} {:>12} {:>14.3}",
            lambda,
            ratio,
            bound,
            ratio >= bound,
            (1.0 - ratio) * lambda.sqrt() / s.l
        );
    }
    println!(
        "\nThe deficit·√λ/L column is ~constant: convergence is Θ(L/√λ),\n\
         so exp(−L²/λ) is eventually optimistic. In the paper's λ = Θ(L²)\n\
         operating regime the bound is valid (it is loose there)."
    );
}
