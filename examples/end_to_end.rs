//! End-to-end driver: the full three-layer system on the paper's §B
//! workloads.
//!
//! 1. Loads the AOT artifacts (Pallas/JAX kernels compiled to HLO by
//!    `make artifacts`) into the PJRT runtime and verifies the compiled
//!    energies match the native factor-graph energies — proof that
//!    L1 (Pallas) → L2 (JAX) → L3 (Rust) compose.
//! 2. Runs the paper's experiments (Ising + Potts, all five samplers)
//!    through the multi-chain coordinator.
//! 3. Emits the Figure 1 / 2(a) / 2(b) / 2(c) trajectory CSVs and prints
//!    the headline comparison (who converges, at what per-iteration cost).
//!
//! Run with: `cargo run --release --example end_to_end [-- --full]`
//! (default 100k iterations per sampler; `--full` uses the paper's 10⁶).

use std::path::Path;

use mbgibbs::bench::figures::{run_figure, FigureParams};
use mbgibbs::bench::workload;
use mbgibbs::graph::models;
use mbgibbs::runtime::{backend::parity_report, ArtifactStore, XlaDenseBackend};

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let out = Path::new("bench_out/end_to_end");

    // ---- Stage 1: AOT artifacts → PJRT runtime → parity with native ----
    // Optional: the compiled kernels only exist after `make artifacts`,
    // and stages 2–3 exercise the pure-Rust path regardless, so a missing
    // artifact store degrades to a skip instead of an abort (this keeps
    // the example runnable in CI, which has no Python toolchain).
    println!("=== stage 1: artifact load + L1/L2/L3 parity ===");
    match ArtifactStore::open(Path::new("artifacts")) {
        Ok(store) => {
            println!("artifacts: {:?}", store.names());
            for (name, model) in [
                ("potts", models::paper_potts()),
                ("ising", models::paper_ising()),
            ] {
                let backend = XlaDenseBackend::new(&store, &model)?;
                let worst = parity_report(&backend, &model, 2, 3)?;
                println!("  {name}: max |xla − native| = {worst:.2e} (float32 tolerance)");
                anyhow::ensure!(worst < 2e-3, "parity check failed for {name}");
            }
        }
        Err(e) => {
            println!("  skipping: no artifact store ({e:#})");
            println!("  run `make artifacts` first to exercise the XLA parity check");
        }
    }

    // ---- Stage 2+3: the paper's experiments through the coordinator ----
    let params = if full {
        FigureParams::default() // 10⁶ iterations, the paper's setting
    } else {
        FigureParams {
            iters: 50_000,
            record_every: 2_500,
            seed: 42,
        }
    };
    println!(
        "\n=== stage 2: paper experiments ({} iterations/sampler) ===",
        params.iters
    );

    let figures: Vec<(&str, _)> = vec![
        ("figure1 min-gibbs ising", workload::fig1_workload()),
        ("figure2a local minibatch ising", workload::fig2a_workload()),
        ("figure2b mgpmh potts", workload::fig2b_workload()),
        ("figure2c doublemin potts", workload::fig2c_workload()),
    ];
    for (title, (model, specs)) in figures {
        println!("\n--- {title} ---");
        let (traj, summary) = run_figure(title, &model, &specs, &params);
        println!("{}", summary.render());
        summary.write_csv(out)?;
        let p = traj.write_csv(out)?;
        println!("(trajectories: {})", p.display());

        // Headline check: every sampler's running-marginal error must
        // shrink from the unmixed start, and the minibatched samplers
        // must do less work per iteration than exact Gibbs on these
        // models wherever the paper claims a win.
        let first: f64 = traj.rows.first().unwrap()[1].parse().unwrap();
        for col in 1..traj.headers.len() {
            let last: f64 = traj.rows.last().unwrap()[col].parse().unwrap();
            anyhow::ensure!(
                last < first.max(0.3),
                "{title}: sampler {} failed to converge (error {last})",
                traj.headers[col]
            );
        }
    }

    println!("\nend_to_end OK — CSVs under {}", out.display());
    Ok(())
}
