//! Domain example: image denoising with a Potts prior.
//!
//! A classic factor-graph workload: a grid-local Potts smoothness prior
//! (pair factors) plus per-pixel unary evidence (table factors) from a
//! noisy label image. Gibbs sampling recovers the clean labels; we compare
//! vanilla Gibbs and Local Minibatch Gibbs (Algorithm 3) on wall-clock and
//! pixel accuracy, and report the posterior-marginal decode.
//!
//! Run with: `cargo run --release --example potts_denoise`

use mbgibbs::analysis::MarginalEstimator;
use mbgibbs::graph::{FactorGraph, FactorGraphBuilder};
use mbgibbs::rng::{Pcg64, Rng};
use mbgibbs::samplers::{EnergyPath, GibbsSampler, LocalMinibatchSampler, Sampler};
use std::time::Instant;

const SIDE: usize = 48;
const D: u16 = 4; // label count
const SMOOTH: f64 = 0.9; // Potts smoothness weight
const EVIDENCE: f64 = 1.4; // log-likelihood weight of the observed label
const NOISE: f64 = 0.35; // fraction of corrupted pixels

/// Ground truth: four quadrant labels plus a diagonal stripe.
fn ground_truth() -> Vec<u16> {
    let mut img = vec![0u16; SIDE * SIDE];
    for r in 0..SIDE {
        for c in 0..SIDE {
            let mut v = match (r >= SIDE / 2, c >= SIDE / 2) {
                (false, false) => 0,
                (false, true) => 1,
                (true, false) => 2,
                (true, true) => 3,
            };
            if r.abs_diff(c) < 4 {
                v = (v + 1) % D as usize;
            }
            img[r * SIDE + c] = v as u16;
        }
    }
    img
}

fn corrupt(truth: &[u16], rng: &mut Pcg64) -> Vec<u16> {
    truth
        .iter()
        .map(|&v| {
            if rng.bernoulli(NOISE) {
                rng.index(D as usize) as u16
            } else {
                v
            }
        })
        .collect()
}

/// Grid Potts prior + unary evidence from the noisy image.
fn build_model(noisy: &[u16]) -> FactorGraph {
    let mut b = FactorGraphBuilder::new(SIDE * SIDE, D);
    for r in 0..SIDE {
        for c in 0..SIDE {
            let i = (r * SIDE + c) as u32;
            if c + 1 < SIDE {
                b.add_potts_pair(i, i + 1, SMOOTH);
            }
            if r + 1 < SIDE {
                b.add_potts_pair(i, i + SIDE as u32, SMOOTH);
            }
            // evidence: log-potential EVIDENCE for the observed label
            let mut table = vec![0.0f64; D as usize];
            table[noisy[i as usize] as usize] = EVIDENCE;
            b.add_table(vec![i], table);
        }
    }
    b.build()
}

fn accuracy(a: &[u16], b: &[u16]) -> f64 {
    let same = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    same as f64 / a.len() as f64
}

fn denoise(g: &FactorGraph, noisy: &[u16], sampler: &mut dyn Sampler, iters: u64) -> (Vec<u16>, f64) {
    let mut rng = Pcg64::seeded(99);
    let mut state = noisy.to_vec();
    sampler.reset(&state, &mut rng);
    let mut marg = MarginalEstimator::new(g.n(), D as usize);
    let start = Instant::now();
    let burnin = iters / 5;
    for it in 0..iters {
        sampler.step(&mut state, &mut rng);
        if it >= burnin {
            marg.update(&state);
        }
    }
    let secs = start.elapsed().as_secs_f64();
    // marginal decode: argmax posterior label per pixel
    let decoded: Vec<u16> = (0..g.n())
        .map(|i| {
            let p = marg.marginal(i);
            p.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u16
        })
        .collect();
    (decoded, secs)
}

fn main() {
    let mut rng = Pcg64::seeded(5);
    let truth = ground_truth();
    let noisy = corrupt(&truth, &mut rng);
    let g = build_model(&noisy);
    let stats = g.stats().clone();
    println!(
        "Potts denoising: {SIDE}×{SIDE}, D = {D}, n = {}, Δ = {}, noisy accuracy = {:.3}\n",
        g.n(),
        stats.delta,
        accuracy(&noisy, &truth)
    );

    let iters = (g.n() as u64) * 600; // ~600 sweeps
    println!("{:<22} {:>12} {:>10} {:>12}", "sampler", "accuracy", "seconds", "iters");
    {
        let mut s = GibbsSampler::new(&g, EnergyPath::Specialized);
        let (decoded, secs) = denoise(&g, &noisy, &mut s, iters);
        println!(
            "{:<22} {:>12.4} {:>10.2} {:>12}",
            "gibbs",
            accuracy(&decoded, &truth),
            secs,
            iters
        );
    }
    {
        // B = 3 of ≤ 5 local factors: Algorithm 3 with a 60% batch.
        let mut s = LocalMinibatchSampler::new(&g, 3);
        let (decoded, secs) = denoise(&g, &noisy, &mut s, iters);
        println!(
            "{:<22} {:>12.4} {:>10.2} {:>12}",
            "local-minibatch B=3",
            accuracy(&decoded, &truth),
            secs,
            iters
        );
    }
    println!(
        "\nBoth samplers lift accuracy well above the noisy input. Note the\n\
         contrast with the dense paper models: at Δ = 5 minibatching buys\n\
         nothing (B·D ≈ Δ + D already) and the subsampling bias costs\n\
         accuracy — matching the paper's premise that minibatch Gibbs is\n\
         for LARGE local neighborhoods."
    );
}
