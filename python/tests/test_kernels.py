"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import minibatch_energy, potts_energy, ref


def rand_state(rng, n, d):
    x = rng.integers(0, d, size=n)
    return jax.nn.one_hot(x, d, dtype=jnp.float32)


def rand_w(rng, n):
    w = rng.random((n, n), dtype=np.float32)
    np.fill_diagonal(w, 0.0)
    return jnp.asarray(w + w.T)


class TestCondEnergies:
    @pytest.mark.parametrize("n,d", [(4, 2), (20, 3), (128, 10), (400, 10), (400, 2), (513, 7)])
    def test_matches_ref(self, n, d):
        rng = np.random.default_rng(n * 1000 + d)
        w = rand_w(rng, n)
        x = rand_state(rng, n, d)
        beta = 1.7
        got = potts_energy.cond_energies(w, x, beta)
        want = ref.cond_energies_ref(w, x, beta)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_zero_beta(self):
        rng = np.random.default_rng(0)
        w = rand_w(rng, 16)
        x = rand_state(rng, 16, 4)
        got = potts_energy.cond_energies(w, x, 0.0)
        assert np.allclose(got, 0.0)

    def test_identity_structure(self):
        # Two variables, one interaction: energies read off directly.
        w = jnp.array([[0.0, 2.0], [2.0, 0.0]], dtype=jnp.float32)
        x = jax.nn.one_hot(jnp.array([0, 1]), 3, dtype=jnp.float32)
        e = potts_energy.cond_energies(w, x, 1.0)
        # E[0, u] = 2 * onehot(x1)[u] = 2*delta(u,1)
        np.testing.assert_allclose(e[0], [0.0, 2.0, 0.0], atol=1e-6)
        np.testing.assert_allclose(e[1], [2.0, 0.0, 0.0], atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=200),
        d=st.integers(min_value=2, max_value=16),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        beta=st.floats(min_value=0.0, max_value=8.0),
    )
    def test_hypothesis_shapes(self, n, d, seed, beta):
        rng = np.random.default_rng(seed)
        w = rand_w(rng, n)
        x = rand_state(rng, n, d)
        got = potts_energy.cond_energies(w, x, beta)
        want = ref.cond_energies_ref(w, x, beta)
        assert got.shape == (n, d)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


class TestWeightedCondEnergies:
    @pytest.mark.parametrize("n,d", [(16, 4), (400, 10)])
    def test_matches_ref(self, n, d):
        rng = np.random.default_rng(7)
        w = rand_w(rng, n)
        x = rand_state(rng, n, d)
        # sparse Poisson-style weights: mostly zero
        weights = jnp.asarray(
            rng.poisson(0.05, size=n).astype(np.float32) * rng.random(n).astype(np.float32) * 3.0
        )
        got = potts_energy.weighted_cond_energies(w, x, weights, 2.3)
        want = ref.weighted_cond_energies_ref(w, x, weights, 2.3)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_zero_weights_zero_energy(self):
        rng = np.random.default_rng(3)
        w = rand_w(rng, 32)
        x = rand_state(rng, 32, 5)
        got = potts_energy.weighted_cond_energies(w, x, jnp.zeros(32), 1.0)
        assert np.allclose(got, 0.0)


class TestMinibatchEstimate:
    @pytest.mark.parametrize("m", [1, 7, 1024, 1025, 160000])
    def test_matches_ref(self, m):
        rng = np.random.default_rng(m)
        phi = jnp.asarray(rng.random(m, dtype=np.float32))
        s = jnp.asarray(rng.poisson(0.1, size=m).astype(np.float32))
        coef = jnp.asarray(1.0 + rng.random(m, dtype=np.float32) * 10)
        got = minibatch_energy.minibatch_estimate(phi, s, coef)
        want = ref.minibatch_estimate_ref(phi, s, coef)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_s_gives_zero(self):
        m = 100
        phi = jnp.ones(m)
        s = jnp.zeros(m)
        coef = jnp.ones(m)
        assert float(minibatch_energy.minibatch_estimate(phi, s, coef)) == 0.0

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=5000),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis(self, m, seed):
        rng = np.random.default_rng(seed)
        phi = jnp.asarray(rng.random(m, dtype=np.float32) * 5)
        s = jnp.asarray(rng.poisson(0.2, size=m).astype(np.float32))
        coef = jnp.asarray(rng.random(m, dtype=np.float32) * 20)
        got = minibatch_energy.minibatch_estimate(phi, s, coef)
        want = ref.minibatch_estimate_ref(phi, s, coef)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestEstimatorUnbiasedness:
    def test_eq2_unbiased_in_exp(self):
        """Monte-Carlo check of Lemma 1: E[exp(eps_x)] == exp(zeta(x)).

        Small factor set so exp moments are stable; this is the python
        mirror of the exact rust-side test in samplers/estimator.rs.
        """
        rng = np.random.default_rng(42)
        m = 8
        phi = rng.random(m) * 0.2  # factor values
        mphi = phi + rng.random(m) * 0.1  # maximum energies >= phi
        psi = mphi.sum()
        lam = 30.0
        coef = psi / (lam * mphi)
        trials = 200000
        s = rng.poisson(lam * mphi / psi, size=(trials, m)).astype(np.float64)
        eps = (s * np.log1p(coef[None, :] * phi[None, :])).sum(axis=1)
        est = np.exp(eps).mean()
        want = np.exp(phi.sum())
        assert abs(est - want) / want < 0.02
