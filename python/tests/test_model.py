"""L2 model graphs: shapes, paper constants, AOT lowering round-trip."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


class TestRbfInteractions:
    def test_shape_and_diagonal(self):
        a = model.rbf_interactions()
        assert a.shape == (400, 400)
        assert np.allclose(np.diag(a), 0.0)

    def test_symmetric(self):
        a = model.rbf_interactions()
        np.testing.assert_allclose(a, a.T, atol=1e-7)

    def test_neighbor_value(self):
        # adjacent grid sites: d^2 = 1 -> A = exp(-1.5)
        a = np.asarray(model.rbf_interactions())
        assert abs(a[0, 1] - np.exp(-1.5)) < 1e-6
        # diagonal neighbors: d^2 = 2
        assert abs(a[0, 21] - np.exp(-3.0)) < 1e-6

    def test_paper_constants_ising(self):
        """Paper §2: Ising (beta=1) has L = 2.21, Psi = 416.1.

        One factor per unordered pair, phi_ij = beta*A_ij*(x_i x_j + 1),
        M_phi = 2*beta*A_ij. Psi = 2*beta*sum_{i<j} A_ij = beta*sum_ij A_ij;
        L = max_i sum_{j != i} 2*beta*A_ij.
        """
        a = np.asarray(model.rbf_interactions(), dtype=np.float64)
        beta = model.ISING_BETA
        psi = beta * a.sum()
        l = 2 * beta * a.sum(axis=1).max()
        assert abs(psi - 416.1) < 0.2, psi
        assert abs(l - 2.21) < 0.01, l

    def test_paper_constants_potts(self):
        """Paper §3: Potts (beta=4.6) has L = 5.09, Psi = 957.1.

        phi_ij = beta*A_ij*delta(x_i,x_j) per unordered pair, M_phi =
        beta*A_ij. Psi = beta*sum_{i<j} A_ij; L = beta*max_i sum_j A_ij.
        """
        a = np.asarray(model.rbf_interactions(), dtype=np.float64)
        beta = model.POTTS_BETA
        psi = beta * a.sum() / 2
        l = beta * a.sum(axis=1).max()
        assert abs(psi - 957.1) < 0.5, psi
        assert abs(l - 5.09) < 0.01, l


class TestGraphs:
    def _setup(self, d):
        rng = np.random.default_rng(0)
        w = model.potts_weights()
        x = jax.nn.one_hot(jnp.asarray(rng.integers(0, d, 400)), d, dtype=jnp.float32)
        return w, x

    def test_cond_energies_graph(self):
        w, x = self._setup(10)
        (e,) = model.cond_energies_graph(w, x, 4.6)
        assert e.shape == (400, 10)
        np.testing.assert_allclose(
            e, ref.cond_energies_ref(w, x, 4.6), rtol=1e-4, atol=1e-3
        )

    def test_total_energy_consistent_with_factor_values(self):
        w, x = self._setup(10)
        (zeta,) = model.total_energy_graph(w, x, 4.6)
        (vals,) = model.potts_factor_values_graph(w, x, 4.6)
        np.testing.assert_allclose(float(zeta), float(vals.sum()), rtol=1e-4)

    def test_ising_identity(self):
        """Ising energy via D=2 Potts: zeta = sum_{i<j} beta*A_ij*(s_i s_j+1)."""
        rng = np.random.default_rng(1)
        spins = rng.integers(0, 2, 400)  # 0 -> -1, 1 -> +1
        a = np.asarray(model.rbf_interactions(), dtype=np.float64)
        s = 2.0 * spins - 1.0
        want = (np.triu(a, 1) * (np.outer(s, s) + 1)).sum()
        x = jax.nn.one_hot(jnp.asarray(spins), 2, dtype=jnp.float32)
        (zeta,) = model.total_energy_graph(model.ising_weights(), x, 1.0)
        np.testing.assert_allclose(float(zeta), want, rtol=1e-4)


class TestAot:
    def test_artifact_specs_complete(self):
        specs = model.artifact_specs()
        assert set(specs) >= {
            "potts_cond_energies",
            "ising_cond_energies",
            "potts_weighted_cond_energies",
            "minibatch_estimate",
            "potts_factor_values",
            "potts_total_energy",
            "ising_total_energy",
        }

    def test_lower_one_to_hlo_text(self):
        fn, shapes = model.artifact_specs()["potts_total_energy"]
        lowered = jax.jit(fn).lower(*shapes)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "f32[400,400]" in text

    def test_lower_all_manifest(self, tmp_path):
        manifest = aot.lower_all(str(tmp_path))
        assert len(manifest) == len(model.artifact_specs())
        for name, meta in manifest.items():
            assert (tmp_path / meta["file"]).exists()
            assert meta["bytes"] > 100
