"""Pallas kernels for dense pairwise conditional energies.

The Gibbs-sampling hot spot for the paper's dense kernel models (fully
connected Ising/Potts with Gaussian-RBF interactions, §B) is

    E[i, u] = beta * sum_j W[i, j] * onehot(x(j))[u]        (all i, all u)

— a (n, n) x (n, D) matmul. On TPU this is exactly MXU territory; the paper
ran it scalar-by-scalar on CPU, so the "hardware adaptation" here is to
tile the contraction for VMEM and feed the systolic array:

  * grid = (m_tiles, k_tiles); each program multiplies a (BM, BK) slab of W
    against a (BK, D') slab of X and accumulates into the (BM, D') output
    block. BM = BK = 128 matches the MXU tile; D is zero-padded to the
    128-lane boundary by the wrapper.
  * The k-grid dimension revisits the same output block ("arbitrary"
    dimension semantics), initializing it at k == 0 — the standard Pallas
    accumulation idiom. HBM->VMEM traffic is one W slab + one X slab per
    step; VMEM footprint is BM*BK + BK*D' + BM*D' floats (~193 KiB at
    BM=BK=D'=128), far under the ~16 MiB/core budget, leaving room for
    double-buffering by the pipeline emitter.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path and what gets
AOT-lowered into the artifacts the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile for the contraction dims. D is padded up to LANE.
BLOCK_M = 128
BLOCK_K = 128
LANE = 128


def _matmul_kernel(w_ref, x_ref, o_ref):
    """One (BM, BK) @ (BK, D') partial product, accumulated over the k grid."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, axis, multiple):
    pad = (-a.shape[axis]) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=())
def cond_energies(w, x_onehot, beta):
    """Pallas conditional-energy table: ``beta * W @ X`` (see ref.py oracle).

    Args:
      w: (n, n) float32 interaction matrix, diagonal zeroed.
      x_onehot: (n, D) float32 one-hot state.
      beta: scalar inverse temperature.

    Returns:
      (n, D) float32 conditional energies for every variable and value.
    """
    n, d = x_onehot.shape
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, BLOCK_M), 1, BLOCK_K)
    xp = _pad_to(_pad_to(x_onehot.astype(jnp.float32), 0, BLOCK_K), 1, LANE)
    mp, kp = wp.shape
    dp = xp.shape[1]

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // BLOCK_M, kp // BLOCK_K),
        in_specs=[
            pl.BlockSpec((BLOCK_M, BLOCK_K), lambda m, k: (m, k)),
            pl.BlockSpec((BLOCK_K, dp), lambda m, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, dp), lambda m, k: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, dp), jnp.float32),
        interpret=True,
    )(wp, xp)
    return beta * out[:n, :d]


def weighted_cond_energies(w, x_onehot, weights, beta):
    """Minibatch-weighted variant: ``beta * (W * weights[None, :]) @ X``.

    Scaling the interaction slab by the sparse Poisson weight vector before
    the contraction keeps the Eq. (2) / Alg. 4 estimator semantics while
    reusing the same MXU schedule (the elementwise scale fuses into the
    HBM->VMEM load on TPU; under interpret mode XLA fuses it on CPU).
    """
    return cond_energies(w * weights[None, :].astype(jnp.float32), x_onehot, beta)
