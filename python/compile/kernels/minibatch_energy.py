"""Pallas kernel for the bias-adjusted minibatch energy estimator, Eq. (2).

    eps_x = sum_phi s_phi * log(1 + coef_phi * phi(x)),
    coef_phi = Psi / (lambda * M_phi)

This is the MIN-Gibbs / DoubleMIN-Gibbs second-stage estimator evaluated
densely over the factor vector (zero Poisson weight == factor not sampled).
It is a bandwidth-bound reduction, not a matmul: the tiling goal is simply
to stream (BLOCK,)-sized slabs of the three input vectors through VMEM and
accumulate one scalar. The log1p runs on the VPU; on TPU the three streams
are consumed at memory speed, so the roofline is HBM bandwidth — the kernel
structure (single pass, no re-reads) is already optimal there.

interpret=True for the same reason as potts_energy.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _estimate_kernel(phi_ref, s_ref, coef_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    contrib = s_ref[...] * jnp.log1p(coef_ref[...] * phi_ref[...])
    o_ref[...] += jnp.sum(contrib, axis=-1, keepdims=True)


def minibatch_estimate(phi, s, coef):
    """Evaluate Eq. (2) over dense per-factor vectors.

    Args:
      phi: (m,) factor values phi(x) >= 0.
      s: (m,) Poisson minibatch weights (0 for unsampled factors).
      coef: (m,) per-factor Psi / (lambda * M_phi).

    Returns:
      () float32 scalar estimate eps_x.
    """
    (m,) = phi.shape
    pad = (-m) % BLOCK
    # Zero-padding is exact: s == 0 contributes s * log1p(...) == 0.
    phi_p = jnp.pad(phi.astype(jnp.float32), (0, pad)).reshape(1, -1)
    s_p = jnp.pad(s.astype(jnp.float32), (0, pad)).reshape(1, -1)
    coef_p = jnp.pad(coef.astype(jnp.float32), (0, pad)).reshape(1, -1)
    mp = phi_p.shape[1]

    out = pl.pallas_call(
        _estimate_kernel,
        grid=(mp // BLOCK,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(phi_p, s_p, coef_p)
    return out[0, 0]
