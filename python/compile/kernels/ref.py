"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest + hypothesis sweeps
(see python/tests/test_kernels.py). Keep these functions boring — no pallas,
no custom calls, just jnp.
"""

import jax.numpy as jnp


def cond_energies_ref(w, x_onehot, beta):
    """Conditional-energy table for a dense pairwise model.

    For a Potts-type model with pairwise energy ``beta * W[i,j] *
    delta(x(i), x(j))``, the Gibbs conditional energies of *all* variables
    given the current one-hot state are

        E[i, u] = beta * sum_j W[i, j] * onehot(x(j))[u]

    i.e. a plain matmul ``beta * W @ X``. The caller is responsible for
    zeroing the diagonal of ``W`` and for folding in the symmetry factor
    (each unordered pair appears twice in the paper's double sum).

    Args:
      w: (n, n) float32 interaction matrix (diagonal already zeroed).
      x_onehot: (n, D) float32 one-hot encoding of the state.
      beta: scalar inverse temperature.

    Returns:
      (n, D) float32 table of conditional energies.
    """
    return beta * jnp.dot(w, x_onehot)


def cond_energy_row_ref(w_row, x_onehot, beta):
    """Conditional energies for a single variable: ``beta * w_row @ X``.

    Args:
      w_row: (n,) interaction row of the resampled variable (self-entry 0).
      x_onehot: (n, D) one-hot state.
      beta: scalar inverse temperature.

    Returns:
      (D,) conditional energy vector (eps_u in Algorithm 1 of the paper).
    """
    return beta * jnp.dot(w_row, x_onehot)


def minibatch_estimate_ref(phi, s, coef):
    """Bias-adjusted minibatch energy estimator, Eq. (2) of the paper.

        eps_x = sum_phi s_phi * log(1 + coef_phi * phi(x))

    where ``coef_phi = Psi / (lambda * M_phi)`` and ``s_phi`` are the
    Poisson minibatch weights. Factors with ``s_phi == 0`` contribute
    nothing, so a dense evaluation over all factors equals the paper's
    sparse sum over the sampled subset S.

    Args:
      phi: (m,) factor values phi(x) >= 0.
      s: (m,) Poisson weights (float; integer-valued).
      coef: (m,) per-factor coefficients Psi / (lambda * M_phi).

    Returns:
      scalar estimate eps_x.
    """
    return jnp.sum(s * jnp.log1p(coef * phi))


def weighted_cond_energies_ref(w, x_onehot, weights, beta):
    """Minibatch-weighted conditional energies (MGPMH proposal, Alg. 4).

        E[i, u] = beta * sum_j weights[j] * W[i, j] * onehot(x(j))[u]

    ``weights[j]`` carries the per-factor importance weight
    ``s_phi * L / (lambda * M_phi)`` for the factor (i, j); zero weight
    means the factor was not in the minibatch.
    """
    return beta * jnp.dot(w * weights[None, :], x_onehot)
