"""Layer-1 Pallas kernels (build-time only; never imported at runtime)."""

from . import minibatch_energy, potts_energy, ref  # noqa: F401
