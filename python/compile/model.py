"""Layer 2: JAX compute graphs for the paper's dense lattice models.

Defines (a) the synthetic model construction of the paper's §B — the
Gaussian-RBF interaction matrix on an N x N grid — and (b) the jitted
energy graphs that call the Layer-1 Pallas kernels and get AOT-lowered by
aot.py into the HLO artifacts the Rust runtime executes.

Model conventions (must match rust/src/graph/models.rs exactly):

The paper writes the energies as double sums over (i, j), but its reported
constants (Ising beta=1: L = 2.21, Psi = 416.1; Potts beta=4.6: L = 5.09,
Psi = 957.1) pin down the convention actually used: ONE factor per
UNORDERED pair {i, j}, i < j. With A_ij = exp(-gamma * d_ij^2), A_ii = 0:

  * Potts:  phi_ij(x) = beta * A_ij * delta(x_i, x_j),  M_phi = beta*A_ij
      -> Psi = beta * sum_{i<j} A_ij = 957.1 at beta = 4.6   (checked)
      -> L   = beta * max_i sum_j A_ij = 5.09               (checked)
  * Ising:  phi_ij(x) = beta * A_ij * (x_i x_j + 1),  M_phi = 2*beta*A_ij
      (x_i x_j + 1 = 2*delta(x_i, x_j) for x in {-1,+1}: Ising is the
      D = 2 Potts model with pair weight 2*beta*A_ij)
      -> Psi = 2*beta * sum_{i<j} A_ij = 416.1 at beta = 1    (checked)
      -> L   = 2*beta * max_i sum_j A_ij = 2.21              (checked)

Conditional energies: eps_u(i) = sum_{j != i} w_ij * delta(u, x_j) with
w = beta*A (Potts) or 2*beta*A (Ising) — the kernels take w directly.

All functions are pure and shape-static so `jax.jit(...).lower()` produces
a single self-contained HLO module per (model, shape) configuration.
"""

import jax
import jax.numpy as jnp

from .kernels import minibatch_energy, potts_energy

GRID_N = 20  # paper §B: 20 x 20 lattice
N_VARS = GRID_N * GRID_N
POTTS_D = 10  # paper §3: D = 10
ISING_D = 2
RBF_GAMMA = 1.5  # paper §B
ISING_BETA = 1.0  # paper §B
POTTS_BETA = 4.6  # paper §B


def rbf_interactions(grid_n=GRID_N, gamma=RBF_GAMMA):
    """Gaussian-RBF interaction matrix A of the paper's §B, diagonal zeroed.

    A_ij = exp(-gamma * ||pos_i - pos_j||^2) for i != j on the grid_n x
    grid_n lattice (fully connected: every pair interacts).
    """
    idx = jnp.arange(grid_n * grid_n)
    pos = jnp.stack([idx // grid_n, idx % grid_n], axis=1).astype(jnp.float32)
    d2 = jnp.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
    a = jnp.exp(-gamma * d2)
    return a - jnp.diag(jnp.diag(a))


def potts_weights(grid_n=GRID_N, gamma=RBF_GAMMA):
    """Potts pair-weight matrix W = A (one factor per unordered pair)."""
    return rbf_interactions(grid_n, gamma)


def ising_weights(grid_n=GRID_N, gamma=RBF_GAMMA):
    """Ising pair-weight matrix W = 2A (D = 2 Potts equivalent)."""
    return 2.0 * rbf_interactions(grid_n, gamma)


def one_hot(x, d):
    """(n,) int32 state -> (n, d) float32 one-hot encoding."""
    return jax.nn.one_hot(x, d, dtype=jnp.float32)


# --------------------------------------------------------------------------
# Jitted graphs lowered by aot.py. Each takes the interaction matrix as an
# argument (fed at runtime by Rust, not baked at compile time) so one
# artifact serves any 20x20 dense model, and returns a 1-tuple (the rust
# loader unwraps with to_tuple1).
# --------------------------------------------------------------------------


def cond_energies_graph(w, x_onehot, beta):
    """All-variable conditional-energy table E[i, u] (Pallas matmul)."""
    return (potts_energy.cond_energies(w, x_onehot, beta),)


def weighted_cond_energies_graph(w, x_onehot, weights, beta):
    """Minibatch-weighted conditional energies (MGPMH proposal path)."""
    return (potts_energy.weighted_cond_energies(w, x_onehot, weights, beta),)


def minibatch_estimate_graph(phi, s, coef):
    """Eq. (2) bias-adjusted energy estimate over dense factor vectors."""
    return (minibatch_energy.minibatch_estimate(phi, s, coef),)


def potts_factor_values_graph(w, x_onehot, beta):
    """Per-unordered-pair factor values phi_ij(x) = beta*W_ij*delta(x_i,x_j).

    Emitted as the flattened (n*n,) upper-triangle-masked matrix (row-major;
    entries with j <= i are zero), so entry i*n+j for i < j is the value of
    factor {i, j}. Used by the MIN-Gibbs second minibatch to evaluate
    sampled factors in bulk. sum(vals) == zeta(x).
    """
    agree = jnp.dot(x_onehot, x_onehot.T)  # (n, n) delta(x_i, x_j)
    vals = beta * jnp.triu(w, k=1) * agree
    return (vals.reshape(-1),)


def total_energy_graph(w, x_onehot, beta):
    """zeta(x) = beta * sum_{i<j} W_ij delta(x_i, x_j).

    Computed from the conditional-energy table (each unordered pair is
    counted twice in sum_i eps_{x(i)}(i), hence the 1/2).
    """
    e = potts_energy.cond_energies(w, x_onehot, beta)  # (n, D)
    return (0.5 * jnp.sum(e * x_onehot),)


# --------------------------------------------------------------------------
# "dot" variants: the same math through a plain fused XLA dot instead of
# the Pallas kernel. interpret=True compiles the Pallas grid to an HLO
# while-loop that CPU-PJRT executes orders of magnitude slower than one
# fused dot (see EXPERIMENTS.md §Perf); on a real TPU the Mosaic-compiled
# Pallas kernel IS the fast path and these variants are redundant. The
# Rust backend defaults to the dot variants on CPU and keeps the Pallas
# artifacts as the (numerically identical) validation target.
# --------------------------------------------------------------------------


def cond_energies_dot_graph(w, x_onehot, beta):
    """Conditional-energy table via a fused XLA dot (ref.py math)."""
    from .kernels import ref

    return (ref.cond_energies_ref(w, x_onehot, beta),)


def total_energy_dot_graph(w, x_onehot, beta):
    """Total energy via the fused dot."""
    from .kernels import ref

    e = ref.cond_energies_ref(w, x_onehot, beta)
    return (0.5 * jnp.sum(e * x_onehot),)


def artifact_specs():
    """Static (function, example-shape) specs for every AOT artifact.

    Keyed by artifact name; aot.py lowers each entry to
    ``artifacts/<name>.hlo.txt``.
    """
    f32 = jnp.float32
    n, dp, di = N_VARS, POTTS_D, ISING_D
    w = jax.ShapeDtypeStruct((n, n), f32)
    xp = jax.ShapeDtypeStruct((n, dp), f32)
    xi = jax.ShapeDtypeStruct((n, di), f32)
    wt = jax.ShapeDtypeStruct((n,), f32)
    beta = jax.ShapeDtypeStruct((), f32)
    m = jax.ShapeDtypeStruct((n * n,), f32)
    return {
        "potts_cond_energies": (cond_energies_graph, (w, xp, beta)),
        "ising_cond_energies": (cond_energies_graph, (w, xi, beta)),
        "potts_cond_energies_dot": (cond_energies_dot_graph, (w, xp, beta)),
        "ising_cond_energies_dot": (cond_energies_dot_graph, (w, xi, beta)),
        "potts_weighted_cond_energies": (
            weighted_cond_energies_graph,
            (w, xp, wt, beta),
        ),
        "minibatch_estimate": (minibatch_estimate_graph, (m, m, m)),
        "potts_factor_values": (potts_factor_values_graph, (w, xp, beta)),
        "potts_total_energy": (total_energy_graph, (w, xp, beta)),
        "ising_total_energy": (total_energy_graph, (w, xi, beta)),
        "potts_total_energy_dot": (total_energy_dot_graph, (w, xp, beta)),
        "ising_total_energy_dot": (total_energy_dot_graph, (w, xi, beta)),
    }
