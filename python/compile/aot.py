"""AOT compile path: lower every Layer-2 graph to HLO *text* artifacts.

Run once by `make artifacts`; the Rust runtime
(rust/src/runtime/executor.rs) loads the text with
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

HLO text — NOT ``lowered.compile()`` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (what the published `xla` 0.1.6 crate binds)
rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    """Lower every artifact spec; returns a manifest {name: metadata}."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, arg_shapes) in model.artifact_specs().items():
        lowered = jax.jit(fn).lower(*arg_shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": s.dtype.name}
                for s in arg_shapes
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars -> {path}", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(
            {
                "jax_version": jax.__version__,
                "grid_n": model.GRID_N,
                "n_vars": model.N_VARS,
                "potts_d": model.POTTS_D,
                "ising_d": model.ISING_D,
                "rbf_gamma": model.RBF_GAMMA,
                "artifacts": manifest,
            },
            f,
            indent=2,
        )
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    # legacy single-file mode kept for the Makefile stamp target
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = p.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    manifest = lower_all(out_dir or ".")
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
