//! Snapshot exposition: JSON (machine-readable, round-trips through
//! `mbgibbs metrics`) and Prometheus text format (scrape-compatible).
//!
//! The JSON document shape is:
//!
//! ```json
//! {
//!   "version": 1,
//!   "counters": { "name{labels}": 123 },
//!   "gauges":   { "name": 2.5 },
//!   "histograms": {
//!     "name": { "unit": "ns", "count": 9, "sum": 1024, "mean": 113.7,
//!               "p50": 96.0, "p95": 480.0, "p99": 500.0,
//!               "buckets": [[128, 5], [256, 9]] }
//!   }
//! }
//! ```
//!
//! `buckets` pairs are `[upper_bound, cumulative_count]`, matching
//! Prometheus `le` semantics. Numbers round-trip exactly below 2⁵³;
//! above that (only the top log₂ bucket bound can get there) values
//! saturate, which is fine for display purposes.

use crate::config::json::JsonValue;
use anyhow::{anyhow, Context, Result};

use super::{HistogramSnapshot, Snapshot, Unit};

/// Escape a string for embedding in a JSON document. Metric names carry
/// `{k="v"}` label quotes, so this is not optional. Shared with the
/// service layer's hand-rolled NDJSON responses.
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format an f64 as a JSON number token (non-finite values become null).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn unit_str(u: Unit) -> &'static str {
    match u {
        Unit::None => "",
        Unit::Nanos => "ns",
    }
}

fn unit_of(s: &str) -> Unit {
    match s {
        "ns" => Unit::Nanos,
        _ => Unit::None,
    }
}

/// Render a snapshot as a JSON document.
pub fn to_json(snap: &Snapshot) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str("{\n  \"version\": 1,\n  \"counters\": {");
    for (i, (name, v)) in snap.counters.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    \"{}\": {v}", esc(name)));
    }
    if !snap.counters.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("},\n  \"gauges\": {");
    for (i, (name, v)) in snap.gauges.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n    \"{}\": {}", esc(name), num(*v)));
    }
    if !snap.gauges.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("},\n  \"histograms\": {");
    for (i, h) in snap.histograms.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    \"{}\": {{\"unit\": \"{}\", \"count\": {}, \"sum\": {}, \
             \"mean\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}, \"buckets\": [",
            esc(&h.name),
            unit_str(h.unit),
            h.count,
            h.sum,
            num(h.mean),
            num(h.p50),
            num(h.p95),
            num(h.p99),
        ));
        for (j, (le, cum)) in h.buckets.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("[{le}, {cum}]"));
        }
        out.push_str("]}");
    }
    if !snap.histograms.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("}\n}\n");
    out
}

fn as_u64(v: &JsonValue) -> Option<u64> {
    v.as_f64().map(|f| {
        if f >= u64::MAX as f64 {
            u64::MAX
        } else if f <= 0.0 {
            0
        } else {
            f as u64
        }
    })
}

fn f64_or_nan(v: &JsonValue) -> f64 {
    match v {
        JsonValue::Null => f64::NAN,
        other => other.as_f64().unwrap_or(f64::NAN),
    }
}

/// Parse a JSON document produced by [`to_json`] back into a snapshot.
pub fn from_json(text: &str) -> Result<Snapshot> {
    let doc = JsonValue::parse(text).map_err(|e| anyhow!("invalid metrics JSON: {e}"))?;
    let version = doc
        .get("version")
        .and_then(|v| v.as_f64())
        .context("metrics JSON missing \"version\"")?;
    if version != 1.0 {
        return Err(anyhow!("unsupported metrics snapshot version {version}"));
    }
    let mut snap = Snapshot::default();
    if let Some(obj) = doc.get("counters").and_then(|v| v.as_object()) {
        for (name, v) in obj {
            let v = as_u64(v).with_context(|| format!("counter {name:?} is not a number"))?;
            snap.counters.push((name.clone(), v));
        }
    }
    if let Some(obj) = doc.get("gauges").and_then(|v| v.as_object()) {
        for (name, v) in obj {
            snap.gauges.push((name.clone(), f64_or_nan(v)));
        }
    }
    if let Some(obj) = doc.get("histograms").and_then(|v| v.as_object()) {
        for (name, h) in obj {
            let field = |k: &str| {
                h.get(k)
                    .with_context(|| format!("histogram {name:?} missing {k:?}"))
            };
            let mut buckets = Vec::new();
            for pair in field("buckets")?
                .as_array()
                .with_context(|| format!("histogram {name:?} buckets not an array"))?
            {
                let pair = pair
                    .as_array()
                    .filter(|p| p.len() == 2)
                    .with_context(|| format!("histogram {name:?} bucket is not a pair"))?;
                buckets.push((
                    as_u64(&pair[0]).context("bucket bound not a number")?,
                    as_u64(&pair[1]).context("bucket count not a number")?,
                ));
            }
            snap.histograms.push(HistogramSnapshot {
                name: name.clone(),
                unit: unit_of(field("unit")?.as_str().unwrap_or("")),
                count: as_u64(field("count")?).context("count not a number")?,
                sum: as_u64(field("sum")?).context("sum not a number")?,
                mean: f64_or_nan(field("mean")?),
                p50: f64_or_nan(field("p50")?),
                p95: f64_or_nan(field("p95")?),
                p99: f64_or_nan(field("p99")?),
                buckets,
            });
        }
    }
    // BTreeMap iteration is already sorted; keep the Snapshot invariant.
    Ok(snap)
}

/// Split `base{labels}` into `(base, Some("labels"))`, or `(name, None)`
/// when unlabeled.
fn split_name(name: &str) -> (&str, Option<&str>) {
    match name.split_once('{') {
        Some((base, rest)) => (base, Some(rest.trim_end_matches('}'))),
        None => (name, None),
    }
}

/// Sanitize a metric base name for Prometheus (`[a-zA-Z_][a-zA-Z0-9_]*`).
fn prom_name(base: &str) -> String {
    let mut out: String = base
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Join existing labels with an extra `le` label for histogram buckets.
fn with_le(labels: Option<&str>, le: &str) -> String {
    match labels {
        Some(l) if !l.is_empty() => format!("{{{l},le=\"{le}\"}}"),
        _ => format!("{{le=\"{le}\"}}"),
    }
}

fn plain_labels(labels: Option<&str>) -> String {
    match labels {
        Some(l) if !l.is_empty() => format!("{{{l}}}"),
        _ => String::new(),
    }
}

/// Render a snapshot in the Prometheus text exposition format. `# TYPE`
/// headers are emitted once per metric family (base name).
pub fn to_prometheus(snap: &Snapshot) -> String {
    let mut out = String::with_capacity(1024);
    let mut last_type_hdr = String::new();
    let mut type_hdr = |out: &mut String, base: &str, kind: &str| {
        if last_type_hdr != base {
            out.push_str(&format!("# TYPE {base} {kind}\n"));
            last_type_hdr = base.to_string();
        }
    };
    for (name, v) in &snap.counters {
        let (base, labels) = split_name(name);
        let base = prom_name(base);
        type_hdr(&mut out, &base, "counter");
        out.push_str(&format!("{base}{} {v}\n", plain_labels(labels)));
    }
    for (name, v) in &snap.gauges {
        let (base, labels) = split_name(name);
        let base = prom_name(base);
        type_hdr(&mut out, &base, "gauge");
        out.push_str(&format!("{base}{} {}\n", plain_labels(labels), num(*v)));
    }
    for h in &snap.histograms {
        let (base, labels) = split_name(&h.name);
        let base = prom_name(base);
        type_hdr(&mut out, &base, "histogram");
        for (le, cum) in &h.buckets {
            out.push_str(&format!("{base}_bucket{} {cum}\n", with_le(labels, &le.to_string())));
        }
        out.push_str(&format!("{base}_bucket{} {}\n", with_le(labels, "+Inf"), h.count));
        out.push_str(&format!("{base}_sum{} {}\n", plain_labels(labels), h.sum));
        out.push_str(&format!("{base}_count{} {}\n", plain_labels(labels), h.count));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{labeled, MetricsHub};
    use std::time::Duration;

    fn sample_snapshot() -> Snapshot {
        let hub = MetricsHub::new();
        hub.counter(&labeled(
            "sampler_factor_evals_total",
            &[("chain", "0"), ("sampler", "gibbs")],
        ))
        .add(1234);
        hub.counter("runner_chains_total").add(2);
        hub.gauge("sampler_lambda").set(160.0);
        hub.histogram("sampler_minibatch_local_size").record(12);
        hub.histogram("sampler_minibatch_local_size").record(40);
        hub.latency(&labeled("chain_step_latency_ns", &[("chain", "0")]))
            .record(Duration::from_micros(5));
        hub.snapshot()
    }

    #[test]
    fn json_roundtrip() {
        let snap = sample_snapshot();
        let text = to_json(&snap);
        let back = from_json(&text).unwrap();
        assert_eq!(snap, back);
    }

    #[test]
    fn json_escapes_label_quotes() {
        let snap = sample_snapshot();
        let text = to_json(&snap);
        assert!(text.contains(r#"sampler_factor_evals_total{chain=\"0\",sampler=\"gibbs\"}"#));
        // Must still be parseable by the first-party parser.
        assert!(JsonValue::parse(&text).is_ok());
    }

    #[test]
    fn from_json_rejects_bad_version() {
        assert!(from_json("{\"version\": 9}").is_err());
        assert!(from_json("not json").is_err());
    }

    #[test]
    fn prometheus_shape() {
        let snap = sample_snapshot();
        let text = to_prometheus(&snap);
        assert!(text.contains("# TYPE sampler_factor_evals_total counter"));
        assert!(text.contains("sampler_factor_evals_total{chain=\"0\",sampler=\"gibbs\"} 1234"));
        assert!(text.contains("# TYPE sampler_lambda gauge"));
        assert!(text.contains("sampler_lambda 160"));
        assert!(text.contains("# TYPE sampler_minibatch_local_size histogram"));
        assert!(text.contains("sampler_minibatch_local_size_bucket{le=\"16\"} 1"));
        assert!(text.contains("sampler_minibatch_local_size_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("sampler_minibatch_local_size_sum 52"));
        assert!(text.contains("sampler_minibatch_local_size_count 2"));
        assert!(text.contains("chain_step_latency_ns_bucket{chain=\"0\",le=\"+Inf\"} 1"));
    }

    #[test]
    fn prom_name_sanitizes() {
        assert_eq!(prom_name("a.b-c"), "a_b_c");
        assert_eq!(prom_name("0abc"), "_0abc");
    }
}
