//! Lightweight runtime metrics: counters, gauges, and latency histograms.
//!
//! The coordinator publishes per-chain progress through a [`MetricsHub`];
//! everything is lock-cheap (atomics) so metrics never perturb the hot
//! sampling loop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bit-cast f64).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket log-scale latency histogram (nanoseconds).
#[derive(Debug)]
pub struct LatencyHistogram {
    /// Bucket k covers [2^k, 2^(k+1)) ns; 48 buckets ≈ up to 3 days.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    total_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: (0..48).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let bucket = (64 - ns.max(1).leading_zeros() - 1).min(47) as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean recorded latency.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.total_ns.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile (bucket upper bound), q in [0, 1].
    pub fn quantile(&self, q: f64) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * c as f64).ceil() as u64;
        let mut acc = 0u64;
        for (k, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_nanos(1u64 << (k + 1));
            }
        }
        Duration::from_nanos(u64::MAX)
    }
}

/// Named metrics registry shared between coordinator and CLI reporting.
#[derive(Debug, Default)]
pub struct MetricsHub {
    counters: Mutex<Vec<(String, std::sync::Arc<Counter>)>>,
}

impl MetricsHub {
    /// New empty hub.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a named counter.
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        let mut g = self.counters.lock().unwrap();
        if let Some((_, c)) = g.iter().find(|(n, _)| n == name) {
            return c.clone();
        }
        let c = std::sync::Arc::new(Counter::default());
        g.push((name.to_string(), c.clone()));
        c
    }

    /// Snapshot all counters (name, value).
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(n, c)| (n.clone(), c.get()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn gauge_roundtrips() {
        let g = Gauge::default();
        g.set(2.75);
        assert_eq!(g.get(), 2.75);
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let h = LatencyHistogram::new();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_micros(100));
        assert!(h.quantile(0.5) >= Duration::from_micros(2));
        assert!(h.quantile(1.0) >= Duration::from_micros(1000));
    }

    #[test]
    fn hub_reuses_counters() {
        let hub = MetricsHub::new();
        hub.counter("steps").add(5);
        hub.counter("steps").add(2);
        let snap = hub.snapshot();
        assert_eq!(snap, vec![("steps".to_string(), 7)]);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }
}
