//! Runtime observability: counters, gauges, histograms, a typed metrics
//! registry, and structured-event tracing.
//!
//! Layout:
//!
//! * this module — the metric primitives ([`Counter`], [`Gauge`],
//!   [`Histogram`], [`LatencyHistogram`]), the [`MetricsHub`] registry,
//!   the cheap [`Snapshot`] type, and [`SamplerMetrics`] — the shared
//!   instrumentation struct every sampler reports through;
//! * [`expose`] — JSON and Prometheus text exposition of snapshots;
//! * [`trace`] — ring-buffer structured-event recorder with the
//!   compile-out [`trace_event!`](crate::trace_event) macro.
//!
//! Everything on the record path is atomics-only (`Ordering::Relaxed`):
//! metrics never take a lock after registration, so they do not perturb
//! the hot sampling loop. The hub's `Mutex` guards only registration and
//! snapshotting, both of which happen off the per-step path.
//!
//! Naming convention: Prometheus-style base names with `{k="v"}` label
//! suffixes built by [`labeled`], e.g.
//! `sampler_factor_evals_total{chain="0",sampler="gibbs"}`. See
//! `docs/OBSERVABILITY.md` for the full metric inventory.

pub mod expose;
pub mod trace;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bit-cast f64).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of log₂ buckets: bucket k covers [2^k, 2^(k+1)) (bucket 0 also
/// holds zero), so 64 buckets span all of `u64`.
const BUCKETS: usize = 64;

/// Lock-free log₂-bucketed histogram over `u64` values (latencies in
/// nanoseconds, minibatch sizes, ...). Quantiles interpolate linearly
/// within the winning bucket, so they are exact to within a factor-of-two
/// bucket but do not collapse to the bucket's upper bound.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index for a value: floor(log₂ v), with 0 and 1 sharing
    /// bucket 0.
    #[inline]
    fn bucket_of(v: u64) -> usize {
        (63 - v.max(1).leading_zeros()) as usize
    }

    /// Record one value.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean recorded value (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Approximate quantile, q ∈ [0, 1], linearly interpolated within the
    /// winning bucket. Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * c as f64).ceil() as u64).clamp(1, c);
        let mut acc = 0u64;
        for (k, b) in self.buckets.iter().enumerate() {
            let in_bucket = b.load(Ordering::Relaxed);
            if in_bucket == 0 {
                continue;
            }
            if acc + in_bucket >= rank {
                let frac = (rank - acc) as f64 / in_bucket as f64;
                let lo = if k == 0 { 0.0 } else { (k as f64).exp2() };
                let hi = ((k + 1) as f64).exp2();
                return lo + frac * (hi - lo);
            }
            acc += in_bucket;
        }
        // Unreachable while count() is consistent; be defensive anyway.
        f64::MAX
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Cumulative (upper-bound, count ≤ bound) pairs for non-empty
    /// prefixes, trimmed after the last non-empty bucket. Bounds are the
    /// bucket's exclusive upper edge 2^(k+1) (saturated for the top
    /// bucket).
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let raw: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let last = match raw.iter().rposition(|&c| c > 0) {
            Some(i) => i,
            None => return Vec::new(),
        };
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(last + 1);
        for (k, &c) in raw.iter().enumerate().take(last + 1) {
            acc += c;
            let bound = if k + 1 >= 64 { u64::MAX } else { 1u64 << (k + 1) };
            out.push((bound, acc));
        }
        out
    }
}

/// A [`Histogram`] of durations recorded in nanoseconds.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    inner: Histogram,
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        self.inner.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.inner.count()
    }

    /// Mean recorded latency.
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.inner.mean() as u64)
    }

    /// Interpolated quantile, q ∈ [0, 1].
    pub fn quantile(&self, q: f64) -> Duration {
        Duration::from_nanos(self.inner.quantile(q) as u64)
    }

    /// Median latency.
    pub fn p50(&self) -> Duration {
        self.quantile(0.50)
    }

    /// 95th-percentile latency.
    pub fn p95(&self) -> Duration {
        self.quantile(0.95)
    }

    /// 99th-percentile latency.
    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }

    /// The underlying value histogram (nanosecond units).
    pub fn histogram(&self) -> &Histogram {
        &self.inner
    }
}

/// Value unit of a histogram, carried into snapshots and exposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    /// Dimensionless (sizes, counts).
    None,
    /// Nanoseconds (latency histograms).
    Nanos,
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    Latency(Arc<LatencyHistogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
            Metric::Latency(_) => "latency histogram",
        }
    }
}

/// Format a metric name with `{key="value"}` labels appended, e.g.
/// `labeled("sampler_steps_total", &[("chain", "0")])` →
/// `sampler_steps_total{chain="0"}`.
pub fn labeled(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut s = String::with_capacity(name.len() + 16 * labels.len());
    s.push_str(name);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        s.push_str(v);
        s.push('"');
    }
    s.push('}');
    s
}

/// Named metrics registry shared between samplers, coordinator, and the
/// CLI. Handle lookup is a single `HashMap` probe under a registration
/// mutex; the returned `Arc` handles are lock-free thereafter.
#[derive(Debug, Default)]
pub struct MetricsHub {
    inner: Mutex<HashMap<String, Metric>>,
}

impl MetricsHub {
    /// New empty hub.
    pub fn new() -> Self {
        Self::default()
    }

    fn entry<T, F: FnOnce() -> Metric, G: Fn(&Metric) -> Option<T>>(
        &self,
        name: &str,
        make: F,
        view: G,
    ) -> T {
        let mut g = self.inner.lock().unwrap();
        let m = g
            .entry(name.to_string())
            .or_insert_with(make);
        match view(m) {
            Some(t) => t,
            None => panic!(
                "metric {name:?} already registered as a {}",
                m.kind()
            ),
        }
    }

    /// Get or create a named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.entry(
            name,
            || Metric::Counter(Arc::new(Counter::default())),
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    /// Get or create a named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.entry(
            name,
            || Metric::Gauge(Arc::new(Gauge::default())),
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    /// Get or create a named value histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.entry(
            name,
            || Metric::Histogram(Arc::new(Histogram::new())),
            |m| match m {
                Metric::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Get or create a named latency histogram (nanosecond units).
    pub fn latency(&self, name: &str) -> Arc<LatencyHistogram> {
        self.entry(
            name,
            || Metric::Latency(Arc::new(LatencyHistogram::new())),
            |m| match m {
                Metric::Latency(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Point-in-time snapshot of every registered metric, sorted by name
    /// (deterministic output for reports and tests).
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut snap = Snapshot::default();
        for (name, m) in g.iter() {
            match m {
                Metric::Counter(c) => snap.counters.push((name.clone(), c.get())),
                Metric::Gauge(v) => snap.gauges.push((name.clone(), v.get())),
                Metric::Histogram(h) => {
                    snap.histograms.push(HistogramSnapshot::of(name, h, Unit::None));
                }
                Metric::Latency(h) => snap.histograms.push(HistogramSnapshot::of(
                    name,
                    h.histogram(),
                    Unit::Nanos,
                )),
            }
        }
        snap.counters.sort_by(|a, b| a.0.cmp(&b.0));
        snap.gauges.sort_by(|a, b| a.0.cmp(&b.0));
        snap.histograms.sort_by(|a, b| a.name.cmp(&b.name));
        snap
    }
}

/// Frozen view of one histogram.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    /// Full metric name (with labels).
    pub name: String,
    /// Value unit.
    pub unit: Unit,
    /// Sample count.
    pub count: u64,
    /// Sum of recorded values.
    pub sum: u64,
    /// Mean value.
    pub mean: f64,
    /// Median (interpolated).
    pub p50: f64,
    /// 95th percentile (interpolated).
    pub p95: f64,
    /// 99th percentile (interpolated).
    pub p99: f64,
    /// Cumulative (upper bound, count ≤ bound) pairs, trimmed.
    pub buckets: Vec<(u64, u64)>,
}

impl HistogramSnapshot {
    fn of(name: &str, h: &Histogram, unit: Unit) -> Self {
        Self {
            name: name.to_string(),
            unit,
            count: h.count(),
            sum: h.sum(),
            mean: h.mean(),
            p50: h.p50(),
            p95: h.p95(),
            p99: h.p99(),
            buckets: h.cumulative_buckets(),
        }
    }
}

/// Frozen view of every metric in a hub; cheap to clone, serialize, and
/// diff. Produced by [`MetricsHub::snapshot`], rendered by [`expose`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// (name, value), sorted by name.
    pub counters: Vec<(String, u64)>,
    /// (name, value), sorted by name.
    pub gauges: Vec<(String, f64)>,
    /// Histograms, sorted by name.
    pub histograms: Vec<HistogramSnapshot>,
}

impl Snapshot {
    /// Counter value by exact name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    /// Gauge value by exact name.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    /// Histogram by exact name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|h| h.name == name)
    }

    /// Sum of all counters in a labeled family: matches `base` exactly or
    /// `base{...}` with any labels.
    pub fn counter_family_sum(&self, base: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(n, _)| {
                n == base || (n.starts_with(base) && n[base.len()..].starts_with('{'))
            })
            .map(|&(_, v)| v)
            .sum()
    }
}

/// The shared instrumentation struct every sampler reports through.
///
/// All handles live in a [`MetricsHub`] (so snapshots see them) and are
/// updated with relaxed atomics on the step path. A sampler without an
/// attached `SamplerMetrics` pays only an `Option` branch per step; the
/// `hotpath` bench runs uninstrumented and gates the overhead budget.
#[derive(Debug)]
pub struct SamplerMetrics {
    /// Steps taken.
    pub steps: Arc<Counter>,
    /// Factor evaluations — the paper's cost unit.
    pub factor_evals: Arc<Counter>,
    /// MH proposals made (Gibbs-type samplers never increment this).
    pub proposals: Arc<Counter>,
    /// MH proposals accepted.
    pub accepts: Arc<Counter>,
    /// Per-step local (proposal) minibatch size |S|.
    pub minibatch_local: Arc<Histogram>,
    /// Per-estimate global (Eq. 2) minibatch size.
    pub minibatch_global: Arc<Histogram>,
    /// Configured first batch size λ (or B for local minibatch).
    pub lambda: Arc<Gauge>,
    /// Configured second batch size λ₂ (DoubleMIN only).
    pub lambda2: Arc<Gauge>,
    /// Most recent cached energy estimate (ε / ξ) on the augmented space.
    pub estimator_energy: Arc<Gauge>,
}

impl SamplerMetrics {
    /// Register the full metric family in `hub` under `labels` (normally
    /// `[("chain", k), ("sampler", name)]`).
    pub fn register(hub: &MetricsHub, labels: &[(&str, &str)]) -> Arc<Self> {
        Arc::new(Self {
            steps: hub.counter(&labeled("sampler_steps_total", labels)),
            factor_evals: hub.counter(&labeled("sampler_factor_evals_total", labels)),
            proposals: hub.counter(&labeled("sampler_proposals_total", labels)),
            accepts: hub.counter(&labeled("sampler_accepts_total", labels)),
            minibatch_local: hub.histogram(&labeled("sampler_minibatch_local_size", labels)),
            minibatch_global: hub.histogram(&labeled("sampler_minibatch_global_size", labels)),
            lambda: hub.gauge(&labeled("sampler_lambda", labels)),
            lambda2: hub.gauge(&labeled("sampler_lambda2", labels)),
            estimator_energy: hub.gauge(&labeled("sampler_estimator_energy", labels)),
        })
    }

    /// Standalone (unregistered) instance — for tests and benches.
    pub fn detached() -> Arc<Self> {
        Arc::new(Self {
            steps: Arc::new(Counter::default()),
            factor_evals: Arc::new(Counter::default()),
            proposals: Arc::new(Counter::default()),
            accepts: Arc::new(Counter::default()),
            minibatch_local: Arc::new(Histogram::new()),
            minibatch_global: Arc::new(Histogram::new()),
            lambda: Arc::new(Gauge::default()),
            lambda2: Arc::new(Gauge::default()),
            estimator_energy: Arc::new(Gauge::default()),
        })
    }

    /// Empirical acceptance rate; 1.0 for samplers that never propose
    /// (Gibbs-type chains accept by construction).
    pub fn acceptance(&self) -> f64 {
        let p = self.proposals.get();
        if p == 0 {
            1.0
        } else {
            self.accepts.get() as f64 / p as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn gauge_roundtrips() {
        let g = Gauge::default();
        g.set(2.75);
        assert_eq!(g.get(), 2.75);
    }

    #[test]
    fn histogram_bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        let h = Histogram::new();
        // 100 samples, all in bucket [1024, 2048).
        for _ in 0..100 {
            h.record(1500);
        }
        let p50 = h.p50();
        // Interpolation must land strictly inside the bucket, not at the
        // 2048 upper bound the pre-fix quantile returned.
        assert!(p50 > 1024.0 && p50 < 2048.0, "p50 = {p50}");
        let q01 = h.quantile(0.01);
        let q99 = h.quantile(0.99);
        assert!(q01 < q99, "{q01} vs {q99}");
        assert!(h.quantile(1.0) <= 2048.0);
    }

    #[test]
    fn quantile_ordering_across_buckets() {
        let h = Histogram::new();
        for v in [1u64, 2, 4, 8, 1000] {
            h.record(v * 1000);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= 100.0 * 1000.0);
        assert!(h.p50() <= h.p95());
        assert!(h.p95() <= h.p99());
        assert!(h.quantile(1.0) >= 1_000_000.0 && h.quantile(1.0) <= 2_097_152.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.9), 0.0);
        assert!(h.cumulative_buckets().is_empty());
        let l = LatencyHistogram::new();
        assert_eq!(l.mean(), Duration::ZERO);
        assert_eq!(l.quantile(0.9), Duration::ZERO);
    }

    #[test]
    fn latency_histogram_quantiles() {
        let h = LatencyHistogram::new();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_micros(100));
        assert!(h.p50() >= Duration::from_micros(2));
        assert!(h.quantile(1.0) >= Duration::from_micros(1000));
        assert!(h.p50() <= h.p95() && h.p95() <= h.p99());
    }

    #[test]
    fn cumulative_buckets_trimmed_and_monotone() {
        let h = Histogram::new();
        for v in [1u64, 3, 3, 1000] {
            h.record(v);
        }
        let b = h.cumulative_buckets();
        assert_eq!(b.last().unwrap().1, 4);
        assert!(b.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 <= w[1].1));
        // trimmed: last bound covers 1000 (bucket [512,1024) → bound 1024)
        assert_eq!(b.last().unwrap().0, 1024);
    }

    #[test]
    fn hub_reuses_handles_across_types() {
        let hub = MetricsHub::new();
        hub.counter("steps").add(5);
        hub.counter("steps").add(2);
        hub.gauge("lambda").set(3.5);
        hub.histogram("sizes").record(7);
        hub.latency("lat").record(Duration::from_micros(3));
        let snap = hub.snapshot();
        assert_eq!(snap.counter("steps"), Some(7));
        assert_eq!(snap.gauge("lambda"), Some(3.5));
        assert_eq!(snap.histogram("sizes").unwrap().count, 1);
        assert_eq!(snap.histogram("lat").unwrap().unit, Unit::Nanos);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn hub_rejects_type_mismatch() {
        let hub = MetricsHub::new();
        hub.counter("x");
        hub.gauge("x");
    }

    #[test]
    fn labeled_formatting() {
        assert_eq!(labeled("a", &[]), "a");
        assert_eq!(
            labeled("steps", &[("chain", "0"), ("sampler", "gibbs")]),
            "steps{chain=\"0\",sampler=\"gibbs\"}"
        );
    }

    #[test]
    fn snapshot_family_sum() {
        let hub = MetricsHub::new();
        hub.counter(&labeled("evals", &[("chain", "0")])).add(3);
        hub.counter(&labeled("evals", &[("chain", "1")])).add(4);
        hub.counter("evals_other").add(100);
        let snap = hub.snapshot();
        assert_eq!(snap.counter_family_sum("evals"), 7);
    }

    #[test]
    fn sampler_metrics_acceptance() {
        let m = SamplerMetrics::detached();
        assert_eq!(m.acceptance(), 1.0);
        m.proposals.add(4);
        m.accepts.add(3);
        assert_eq!(m.acceptance(), 0.75);
    }

    #[test]
    fn snapshot_is_sorted() {
        let hub = MetricsHub::new();
        hub.counter("zz");
        hub.counter("aa");
        let snap = hub.snapshot();
        assert_eq!(snap.counters[0].0, "aa");
        assert_eq!(snap.counters[1].0, "zz");
    }
}
