//! Lightweight structured-event tracing.
//!
//! A [`TraceBuffer`] is a fixed-capacity, single-writer ring buffer of
//! [`TraceEvent`]s: the runner creates one per chain, the chain thread is
//! the only writer, and the buffer is drained after the thread joins — so
//! recording needs no locks, no atomics, and (after construction) no
//! allocation. Timestamps are nanoseconds from a monotonic per-buffer
//! epoch (`Instant`), so events within one chain are totally ordered.
//!
//! Recording sites go through the [`trace_event!`](crate::trace_event)
//! macro, which compiles to nothing unless the crate is built with the
//! `trace` feature — disabled builds pay zero cost at the call site, not
//! even a branch. The buffer type itself is always compiled so reports
//! can mention trace capacity uniformly.

use std::time::Instant;

/// What kind of event a [`TraceEvent`] records. The meaning of the `a`
/// and `b` payload words depends on the kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// One sampler step: `a` = iteration index, `b` = factor evals so far.
    Step,
    /// A checkpoint write: `a` = iteration index, `b` = unused.
    Checkpoint,
    /// A progress report line: `a` = iteration index, `b` = unused.
    Progress,
    /// Free-form instrumentation point: payload meaning is site-defined.
    Custom,
}

/// One fixed-size trace record. 32 bytes, `Copy`, no heap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Nanoseconds since the owning buffer's epoch.
    pub t_ns: u64,
    /// Chain index the event belongs to.
    pub chain: u32,
    /// Event kind.
    pub kind: EventKind,
    /// First payload word (kind-dependent).
    pub a: u64,
    /// Second payload word (kind-dependent).
    pub b: u64,
}

/// Fixed-capacity single-writer ring buffer of trace events.
///
/// With capacity 0 the buffer is inert: [`record`](Self::record) is a
/// no-op and nothing is allocated.
#[derive(Debug)]
pub struct TraceBuffer {
    chain: u32,
    epoch: Instant,
    events: Vec<TraceEvent>,
    cap: usize,
    cursor: usize,
    recorded: u64,
}

impl TraceBuffer {
    /// New buffer for `chain` holding at most `cap` events (ring
    /// semantics: oldest events are overwritten once full).
    pub fn new(chain: u32, cap: usize) -> Self {
        Self {
            chain,
            epoch: Instant::now(),
            events: Vec::with_capacity(cap),
            cap,
            cursor: 0,
            recorded: 0,
        }
    }

    /// Chain index this buffer belongs to.
    pub fn chain(&self) -> u32 {
        self.chain
    }

    /// Capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Record one event. No-op when capacity is 0; never allocates after
    /// the buffer first fills.
    #[inline]
    pub fn record(&mut self, kind: EventKind, a: u64, b: u64) {
        if self.cap == 0 {
            return;
        }
        let ev = TraceEvent {
            t_ns: self.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64,
            chain: self.chain,
            kind,
            a,
            b,
        };
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.events[self.cursor] = ev;
        }
        self.cursor = (self.cursor + 1) % self.cap;
        self.recorded += 1;
    }

    /// Retained events, oldest first.
    pub fn events_in_order(&self) -> Vec<TraceEvent> {
        if self.events.len() < self.cap {
            return self.events.clone();
        }
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.cursor..]);
        out.extend_from_slice(&self.events[..self.cursor]);
        out
    }
}

/// Record a structured event into a [`TraceBuffer`], compiled out
/// entirely unless the `trace` cargo feature is enabled.
///
/// ```ignore
/// trace_event!(buf, EventKind::Checkpoint, iter, 0);
/// ```
#[cfg(feature = "trace")]
#[macro_export]
macro_rules! trace_event {
    ($buf:expr, $kind:expr, $a:expr, $b:expr) => {
        $buf.record($kind, $a, $b)
    };
}

/// Disabled-build arm: expands to nothing that executes. The dead branch
/// keeps the bindings "used" so call sites compile identically with the
/// feature off, without evaluating any argument.
#[cfg(not(feature = "trace"))]
#[macro_export]
macro_rules! trace_event {
    ($buf:expr, $kind:expr, $a:expr, $b:expr) => {
        if false {
            let _ = (&mut $buf, $kind, $a, $b);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_with_monotone_timestamps() {
        let mut buf = TraceBuffer::new(3, 16);
        for i in 0..5u64 {
            buf.record(EventKind::Step, i, i * 10);
        }
        let evs = buf.events_in_order();
        assert_eq!(evs.len(), 5);
        assert_eq!(buf.recorded(), 5);
        assert!(evs.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        assert_eq!(evs[4].a, 4);
        assert_eq!(evs[4].b, 40);
        assert!(evs.iter().all(|e| e.chain == 3));
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let mut buf = TraceBuffer::new(0, 4);
        for i in 0..10u64 {
            buf.record(EventKind::Custom, i, 0);
        }
        let evs = buf.events_in_order();
        assert_eq!(evs.len(), 4);
        assert_eq!(buf.recorded(), 10);
        assert_eq!(
            evs.iter().map(|e| e.a).collect::<Vec<_>>(),
            vec![6, 7, 8, 9]
        );
    }

    #[test]
    fn zero_capacity_is_inert() {
        let mut buf = TraceBuffer::new(0, 0);
        buf.record(EventKind::Step, 1, 2);
        assert_eq!(buf.recorded(), 0);
        assert!(buf.events_in_order().is_empty());
    }

    #[test]
    fn macro_compiles_both_ways() {
        let mut buf = TraceBuffer::new(0, 2);
        crate::trace_event!(buf, EventKind::Progress, 7, 0);
        // With the feature off the call must not have recorded anything;
        // with it on, exactly one event lands. Both are valid states.
        assert!(buf.recorded() <= 1);
        #[cfg(feature = "trace")]
        assert_eq!(buf.recorded(), 1);
    }
}
