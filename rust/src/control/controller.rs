//! The per-chain adaptive controller.
//!
//! Runs inside `run_one_chain`: every `adapt_every` iterations it diffs
//! the chain's [`SamplerMetrics`] counters over the review window,
//! updates the evals-per-effective-sample figure of merit, checks the
//! marginal-error trajectory for a convergence plateau (freezing further
//! adjustments and requesting an early checkpoint when it finds one),
//! and steers the sampler's hyperparameters per the configured
//! [`ControlPolicy`].

use std::sync::Arc;

use crate::graph::GraphStats;
use crate::metrics::{labeled, Counter, Gauge, MetricsHub, SamplerMetrics};
use crate::samplers::{Hyperparams, Sampler};

use super::policy::ControlPolicy;

/// Multiplicative steering gain: λ ← λ · exp(GAIN · (target − acc)),
/// clamped to one octave per review.
const GAIN: f64 = 2.0;
/// Per-review multiplicative clamp (at most halve / double).
const MAX_STEP: f64 = 2.0;
/// Acceptance floor for the eval-budget policy: below this the chain is
/// too sticky to be worth the eval savings, so the climb reverses up.
const ACCEPT_FLOOR: f64 = 0.2;

/// What the runner should do after a review.
#[derive(Clone, Copy, Debug, Default)]
pub struct ControlAction {
    /// Write an early checkpoint now (plateau detected).
    pub save_checkpoint: bool,
}

/// Detects convergence plateaus in the (iteration, error) trajectory:
/// the relative improvement over the last `window` recorded points has
/// fallen below `rel_tol`.
#[derive(Clone, Copy, Debug)]
pub struct PlateauDetector {
    window: usize,
    rel_tol: f64,
}

impl PlateauDetector {
    /// Plateau = less than `rel_tol` relative improvement across the
    /// trailing `window` trajectory points.
    pub fn new(window: usize, rel_tol: f64) -> Self {
        assert!(window >= 1 && rel_tol >= 0.0);
        Self { window, rel_tol }
    }

    /// Check the trailing window of an error trajectory.
    pub fn is_plateau(&self, trajectory: &[(u64, f64)]) -> bool {
        if trajectory.len() <= self.window {
            return false;
        }
        let a = trajectory[trajectory.len() - 1 - self.window].1;
        let b = trajectory[trajectory.len() - 1].1;
        a.is_finite() && b.is_finite() && a > 0.0 && (a - b) / a < self.rel_tol
    }
}

/// Counter totals at the last review; deltas against these form the
/// review window.
#[derive(Clone, Copy, Debug, Default)]
struct CounterSnap {
    steps: u64,
    proposals: u64,
    accepts: u64,
    evals: u64,
}

impl CounterSnap {
    fn take(m: &SamplerMetrics) -> Self {
        Self {
            steps: m.steps.get(),
            proposals: m.proposals.get(),
            accepts: m.accepts.get(),
            evals: m.factor_evals.get(),
        }
    }
}

/// Per-chain adaptive controller. Construct with [`Controller::new`]
/// (returns `None` for [`ControlPolicy::Off`]), then from the chain loop
/// call [`Controller::due`] each iteration and [`Controller::review`]
/// when it fires.
pub struct Controller {
    policy: ControlPolicy,
    every: u64,
    m: Arc<SamplerMetrics>,
    delta: usize,
    psi: f64,
    lambda_min: f64,
    lambda_max: f64,
    last: CounterSnap,
    plateau: PlateauDetector,
    frozen: bool,
    settled: bool,
    /// Eval-budget hill-climb state.
    climb_factor: f64,
    prev_cost: Option<f64>,
    adjustments: Arc<Counter>,
    g_lambda: Arc<Gauge>,
    g_lambda2: Arc<Gauge>,
    g_batch: Arc<Gauge>,
    g_evals_per_ess: Arc<Gauge>,
    g_plateau: Arc<Gauge>,
    g_settled_iter: Arc<Gauge>,
}

impl Controller {
    /// Build a controller for one chain, registering its gauges
    /// (`controller_lambda`, `controller_lambda2`, `controller_batch`,
    /// `controller_evals_per_ess`, `controller_plateau`,
    /// `controller_settled_iter`) and the `controller_adjustments_total`
    /// counter in `hub`, all labeled `{chain}`. Returns `None` when the
    /// policy is [`ControlPolicy::Off`].
    pub fn new(
        policy: &ControlPolicy,
        hub: &MetricsHub,
        chain: &str,
        m: Arc<SamplerMetrics>,
        stats: &GraphStats,
    ) -> Option<Self> {
        if policy.is_off() {
            return None;
        }
        let lbl = |name: &str| labeled(name, &[("chain", chain)]);
        // Snapshot the (possibly resume-seeded) counters now so the first
        // window covers only iterations reviewed by THIS controller.
        let last = CounterSnap::take(&m);
        Some(Self {
            policy: *policy,
            every: policy.adapt_every().max(1),
            delta: stats.delta,
            psi: stats.psi,
            lambda_min: 1e-3,
            lambda_max: (stats.psi * stats.psi).max(1e6),
            last,
            plateau: PlateauDetector::new(8, 0.02),
            frozen: false,
            settled: false,
            climb_factor: 0.8,
            prev_cost: None,
            adjustments: hub.counter(&lbl("controller_adjustments_total")),
            g_lambda: hub.gauge(&lbl("controller_lambda")),
            g_lambda2: hub.gauge(&lbl("controller_lambda2")),
            g_batch: hub.gauge(&lbl("controller_batch")),
            g_evals_per_ess: hub.gauge(&lbl("controller_evals_per_ess")),
            g_plateau: hub.gauge(&lbl("controller_plateau")),
            g_settled_iter: hub.gauge(&lbl("controller_settled_iter")),
            m,
        })
    }

    /// Whether a review is due after `completed` iterations. Never fires
    /// once a plateau froze the controller.
    pub fn due(&self, completed: u64) -> bool {
        !self.frozen && completed > 0 && completed % self.every == 0
    }

    /// The sweep-aligned variant of [`Controller::due`]: whether a
    /// review boundary (a multiple of `adapt_every`) lies in
    /// `(prev, now]`. Chromatic engines advance in whole-sweep slices,
    /// so a slice end need not land exactly on a multiple; a review
    /// fires at the first sweep barrier on or after each boundary.
    /// Never fires once a plateau froze the controller.
    pub fn due_crossing(&self, prev: u64, now: u64) -> bool {
        !self.frozen && now > prev && now / self.every > prev / self.every
    }

    /// Mirror the sampler's current hyperparameters into the controller
    /// gauges (called once at chain start and after every adjustment).
    pub fn publish(&self, sampler: &dyn Sampler) {
        let hp = sampler.hyperparams();
        if let Some(l) = hp.lambda {
            self.g_lambda.set(l);
        }
        if let Some(l2) = hp.lambda2 {
            self.g_lambda2.set(l2);
        }
        if let Some(b) = hp.batch {
            self.g_batch.set(b as f64);
        }
    }

    /// Review the chain after `completed` iterations: update the figure
    /// of merit, detect plateaus, and steer the sampler.
    pub fn review(
        &mut self,
        completed: u64,
        sampler: &mut dyn Sampler,
        trajectory: &[(u64, f64)],
    ) -> ControlAction {
        let cur = CounterSnap::take(&self.m);
        let w = CounterSnap {
            steps: cur.steps - self.last.steps,
            proposals: cur.proposals - self.last.proposals,
            accepts: cur.accepts - self.last.accepts,
            evals: cur.evals - self.last.evals,
        };
        self.last = cur;
        if w.steps == 0 {
            return ControlAction::default();
        }

        // Figure of merit: factor evals per effective sample. The crude
        // ESS proxy is accepted moves for MH chains; Gibbs-type chains
        // move every step.
        let ess = if w.proposals > 0 {
            w.accepts.max(1) as f64
        } else {
            w.steps as f64
        };
        self.g_evals_per_ess.set(w.evals as f64 / ess);

        // Convergence plateau → freeze adjustments, request an early
        // checkpoint so the settled chain is durably saved.
        if self.plateau.is_plateau(trajectory) {
            self.frozen = true;
            self.g_plateau.set(1.0);
            return ControlAction {
                save_checkpoint: true,
            };
        }

        let acc = if w.proposals > 0 {
            Some(w.accepts as f64 / w.proposals as f64)
        } else {
            None
        };
        match self.policy {
            ControlPolicy::Off => {}
            ControlPolicy::TargetAcceptance { target, band, .. } => {
                self.review_target(completed, sampler, target, band, acc);
            }
            ControlPolicy::EvalBudget { .. } => {
                self.review_budget(sampler, w.evals as f64 / ess, acc);
            }
        }
        ControlAction::default()
    }

    /// Target-acceptance steering.
    fn review_target(
        &mut self,
        completed: u64,
        sampler: &mut dyn Sampler,
        target: f64,
        band: f64,
        acc: Option<f64>,
    ) {
        let hp = sampler.hyperparams();
        match acc {
            Some(a) => {
                if (a - target).abs() <= band {
                    self.mark_settled(completed);
                    return;
                }
                // Larger λ → proposal closer to the exact conditional →
                // higher acceptance (Theorem 4): steer multiplicatively.
                let factor = (GAIN * (target - a)).exp().clamp(1.0 / MAX_STEP, MAX_STEP);
                if let Some(l) = hp.lambda {
                    let nl = (l * factor).clamp(self.lambda_min, self.lambda_max);
                    self.apply(sampler, Hyperparams::with_lambda(nl));
                } else if let Some(b) = hp.batch {
                    self.apply_batch(sampler, b, factor);
                }
            }
            None => {
                // Gibbs-type chains accept by construction; read the
                // target as a spectral-penalty bound exp(−δ) ≥ target
                // and glide toward the Lemma-2 recipe λ* = 2Ψ²/δ.
                let delta_star = -(target.clamp(0.01, 0.99)).ln();
                if let Some(l) = hp.lambda {
                    let l_star =
                        (2.0 * self.psi * self.psi / delta_star).clamp(self.lambda_min, self.lambda_max);
                    if (l_star / l).ln().abs() > 0.05 {
                        let nl = l * (l_star / l).clamp(1.0 / MAX_STEP, MAX_STEP);
                        self.apply(sampler, Hyperparams::with_lambda(nl));
                    } else {
                        self.mark_settled(completed);
                    }
                } else if let Some(b) = hp.batch {
                    // Local minibatch: B* ≈ target fraction of the degree.
                    let b_star = ((target * self.delta as f64).ceil() as usize).max(1);
                    if b == b_star {
                        self.mark_settled(completed);
                    } else {
                        let factor = (b_star as f64 / b as f64).clamp(1.0 / MAX_STEP, MAX_STEP);
                        self.apply_batch(sampler, b, factor);
                    }
                }
            }
        }
    }

    /// Eval-budget hill-climb: shrink λ (or B) while the windowed
    /// evals-per-effective-sample keeps improving, reverse when it
    /// worsens, and force the climb up below the acceptance floor.
    fn review_budget(&mut self, sampler: &mut dyn Sampler, cost: f64, acc: Option<f64>) {
        if let Some(a) = acc {
            if a < ACCEPT_FLOOR && self.climb_factor < 1.0 {
                self.climb_factor = 1.0 / self.climb_factor;
                self.prev_cost = None;
            }
        }
        if let Some(prev) = self.prev_cost {
            if cost > prev * 1.02 {
                self.climb_factor = 1.0 / self.climb_factor;
            }
        }
        self.prev_cost = Some(cost);
        let hp = sampler.hyperparams();
        if let Some(l) = hp.lambda {
            let nl = (l * self.climb_factor).clamp(self.lambda_min, self.lambda_max);
            self.apply(sampler, Hyperparams::with_lambda(nl));
        } else if let Some(b) = hp.batch {
            self.apply_batch(sampler, b, self.climb_factor);
        }
    }

    /// Apply a batch-size change scaled by `factor`, rounded and clamped
    /// to [1, Δ] (a batch above the max degree buys nothing).
    fn apply_batch(&mut self, sampler: &mut dyn Sampler, b: usize, factor: f64) {
        let scaled = (b as f64 * factor).round() as usize;
        // `round` alone can no-op for small B (e.g. B = 1, factor 1.25);
        // force at least one unit of movement in the factor's direction.
        let nb = if factor > 1.0 {
            scaled.max(b + 1)
        } else if factor < 1.0 {
            scaled.min(b.saturating_sub(1))
        } else {
            scaled
        }
        .clamp(1, self.delta.max(1));
        self.apply(sampler, Hyperparams::with_batch(nb));
    }

    /// Push a hyperparameter update into the sampler; on any actual
    /// change, bump the adjustments counter and republish both the
    /// sampler's gauges and the controller's.
    fn apply(&mut self, sampler: &mut dyn Sampler, hp: Hyperparams) {
        if sampler.set_hyperparams(&hp) {
            self.adjustments.add(1);
            sampler.publish_hyperparams(&self.m);
            self.publish(sampler);
        }
    }

    /// Record the first iteration at which the chain was in-target.
    fn mark_settled(&mut self, completed: u64) {
        if !self.settled {
            self.settled = true;
            self.g_settled_iter.set(completed as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::metrics::MetricsHub;
    use crate::rng::Pcg64;
    use crate::samplers::{LocalMinibatchSampler, MgpmhSampler, MinGibbsSampler};

    #[test]
    fn plateau_detector_wants_flat_trailing_window() {
        let det = PlateauDetector::new(3, 0.02);
        // Still improving fast.
        let falling: Vec<(u64, f64)> = (0..8).map(|i| (i, 1.0 / (i + 1) as f64)).collect();
        assert!(!det.is_plateau(&falling));
        // Flat tail.
        let mut flat = falling.clone();
        flat.extend((8..16).map(|i| (i, 0.1)));
        assert!(det.is_plateau(&flat));
        // Too short to judge.
        assert!(!det.is_plateau(&flat[..3]));
    }

    fn harness(
        policy: ControlPolicy,
    ) -> (crate::graph::FactorGraph, MetricsHub, ControlPolicy) {
        let g = models::tiny_random(4, 3, 0.8, 51);
        (g, MetricsHub::new(), policy)
    }

    /// Over-large λ + high acceptance → the controller must shrink λ and
    /// count the adjustment.
    #[test]
    fn target_policy_shrinks_overlarge_lambda() {
        let (g, hub, policy) = harness(ControlPolicy::target_acceptance(0.7));
        let m = SamplerMetrics::register(&hub, &[("chain", "0"), ("sampler", "mgpmh")]);
        let mut s = MgpmhSampler::new(&g, 400.0);
        s.attach_metrics(m.clone());
        let mut c = Controller::new(&policy, &hub, "0", m, g.stats()).unwrap();
        assert!(c.due(1_000));
        assert!(!c.due(999));

        let mut rng = Pcg64::seeded(52);
        let mut state = vec![0u16; g.n()];
        for _ in 0..1_000 {
            s.step(&mut state, &mut rng);
        }
        let action = c.review(1_000, &mut s, &[]);
        assert!(!action.save_checkpoint);
        assert!(s.lambda() < 400.0, "λ should shrink, got {}", s.lambda());
        let snap = hub.snapshot();
        assert_eq!(
            snap.counter("controller_adjustments_total{chain=\"0\"}"),
            Some(1)
        );
        assert_eq!(
            snap.gauge("controller_lambda{chain=\"0\"}"),
            Some(s.lambda())
        );
        // The sampler's own gauge tracks the retuned value too.
        assert_eq!(
            snap.gauge("sampler_lambda{chain=\"0\",sampler=\"mgpmh\"}"),
            Some(s.lambda())
        );
        assert!(snap
            .gauge("controller_evals_per_ess{chain=\"0\"}")
            .unwrap()
            > 0.0);
    }

    /// A flat error trajectory freezes the controller: the review
    /// requests a checkpoint and no further reviews come due.
    #[test]
    fn plateau_freezes_further_reviews() {
        let (g, hub, policy) = harness(ControlPolicy::target_acceptance(0.7));
        let m = SamplerMetrics::register(&hub, &[("chain", "0"), ("sampler", "mgpmh")]);
        let mut s = MgpmhSampler::new(&g, 4.0);
        s.attach_metrics(m.clone());
        let mut c = Controller::new(&policy, &hub, "0", m, g.stats()).unwrap();

        let mut rng = Pcg64::seeded(53);
        let mut state = vec![0u16; g.n()];
        for _ in 0..1_000 {
            s.step(&mut state, &mut rng);
        }
        let flat: Vec<(u64, f64)> = (0..12).map(|i| (i * 100, 0.25)).collect();
        let action = c.review(1_000, &mut s, &flat);
        assert!(action.save_checkpoint);
        assert!(!c.due(2_000), "frozen controller must not come due");
        assert_eq!(
            hub.snapshot().gauge("controller_plateau{chain=\"0\"}"),
            Some(1.0)
        );
    }

    /// Gibbs-type glide: MIN-Gibbs has no acceptance rate, so the
    /// controller steers λ toward the Lemma-2 recipe 2Ψ²/δ.
    #[test]
    fn gibbs_type_glides_toward_recipe() {
        let (g, hub, policy) = harness(ControlPolicy::target_acceptance(0.7));
        let m = SamplerMetrics::register(&hub, &[("chain", "0"), ("sampler", "min-gibbs")]);
        let psi = g.stats().psi;
        let l_star = 2.0 * psi * psi / -(0.7f64.ln());
        let mut s = MinGibbsSampler::new(&g, l_star * 16.0);
        s.attach_metrics(m.clone());
        let mut c = Controller::new(&policy, &hub, "0", m, g.stats()).unwrap();
        let mut rng = Pcg64::seeded(54);
        let mut state = vec![0u16; g.n()];
        for round in 1..=8u64 {
            for _ in 0..200 {
                s.step(&mut state, &mut rng);
            }
            c.review(round * 200, &mut s, &[]);
        }
        let lam = s.lambda();
        assert!(
            (lam / l_star).ln().abs() <= 0.05,
            "λ = {lam} should have settled near λ* = {l_star}"
        );
        let settled = hub
            .snapshot()
            .gauge("controller_settled_iter{chain=\"0\"}")
            .unwrap();
        assert!(settled > 0.0);
    }

    /// Eval-budget on Local Minibatch: the first move shrinks B (cheaper
    /// window), and B never leaves [1, Δ].
    #[test]
    fn budget_policy_moves_batch_within_bounds() {
        let (g, hub, policy) = harness(ControlPolicy::eval_budget());
        let m = SamplerMetrics::register(&hub, &[("chain", "0"), ("sampler", "local-minibatch")]);
        let delta = g.stats().delta;
        let mut s = LocalMinibatchSampler::new(&g, delta.max(2));
        s.attach_metrics(m.clone());
        let mut c = Controller::new(&policy, &hub, "0", m, g.stats()).unwrap();
        let mut rng = Pcg64::seeded(55);
        let mut state = vec![0u16; g.n()];
        for round in 1..=6u64 {
            for _ in 0..200 {
                s.step(&mut state, &mut rng);
            }
            c.review(round * 200, &mut s, &[]);
            assert!((1..=delta.max(1)).contains(&s.batch()));
        }
        assert!(
            hub.snapshot()
                .counter("controller_adjustments_total{chain=\"0\"}")
                .unwrap()
                > 0
        );
    }

    /// Sweep-aligned reviews fire once per crossed `adapt_every`
    /// boundary, even when slice ends are rounded to whole sweeps.
    #[test]
    fn due_crossing_fires_on_boundary_crossings() {
        let (g, hub, policy) =
            harness(ControlPolicy::target_acceptance(0.7).with_adapt_every(100));
        let m = SamplerMetrics::register(&hub, &[("chain", "0"), ("sampler", "mgpmh")]);
        let c = Controller::new(&policy, &hub, "0", m, g.stats()).unwrap();
        assert!(c.due_crossing(90, 108), "boundary 100 lies in (90, 108]");
        assert!(c.due_crossing(99, 100), "exact landing still fires");
        assert!(!c.due_crossing(100, 108), "boundary 100 already consumed");
        assert!(!c.due_crossing(10, 90), "no boundary crossed");
        assert!(!c.due_crossing(108, 108), "empty slice never fires");
    }

    #[test]
    fn off_policy_builds_no_controller() {
        let g = models::tiny_random(3, 2, 0.5, 56);
        let hub = MetricsHub::new();
        let m = SamplerMetrics::register(&hub, &[("chain", "0"), ("sampler", "gibbs")]);
        assert!(Controller::new(&ControlPolicy::Off, &hub, "0", m, g.stats()).is_none());
    }
}
