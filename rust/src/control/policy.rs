//! Control policies: what the adaptive controller optimizes for.

use anyhow::{bail, Result};

/// Default acceptance-rate target (center of the band).
pub const DEFAULT_TARGET_ACCEPT: f64 = 0.7;
/// Default half-width of the acceptance band around the target.
pub const DEFAULT_BAND: f64 = 0.1;
/// Default review cadence in iterations.
pub const DEFAULT_ADAPT_EVERY: u64 = 1_000;

/// How (and whether) to adapt sampler hyperparameters mid-run.
///
/// Composed into a [`crate::coordinator::RunSpec`] via
/// [`crate::coordinator::RunSpecBuilder::control`]; the runner
/// instantiates one [`super::Controller`] per chain for any policy other
/// than [`ControlPolicy::Off`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ControlPolicy {
    /// No adaptation: hyperparameters stay as configured (the default,
    /// and the paper's a-priori setting).
    Off,
    /// Steer λ (or B) so the windowed acceptance rate lands in
    /// `target ± band`. Gibbs-type samplers, which accept by
    /// construction, reinterpret `target` as the spectral-penalty bound
    /// `exp(−δ) ≥ target` and glide λ toward the paper's Lemma-2 recipe
    /// λ* = 2Ψ²/δ.
    TargetAcceptance {
        /// Acceptance-rate target in (0, 1).
        target: f64,
        /// Half-width of the no-adjustment band around `target`.
        band: f64,
        /// Review the chain every this many iterations.
        adapt_every: u64,
    },
    /// Multiplicative hill-climb on λ (or B) minimizing factor evals per
    /// effective sample, with an acceptance floor so the chain stays
    /// usable.
    EvalBudget {
        /// Review the chain every this many iterations.
        adapt_every: u64,
    },
}

impl Default for ControlPolicy {
    fn default() -> Self {
        Self::Off
    }
}

impl ControlPolicy {
    /// Target-acceptance policy with default band and cadence.
    pub fn target_acceptance(target: f64) -> Self {
        Self::TargetAcceptance {
            target,
            band: DEFAULT_BAND,
            adapt_every: DEFAULT_ADAPT_EVERY,
        }
    }

    /// Eval-budget policy with the default cadence.
    pub fn eval_budget() -> Self {
        Self::EvalBudget {
            adapt_every: DEFAULT_ADAPT_EVERY,
        }
    }

    /// Resolve a policy name (CLI `--adapt NAME`, config `control.policy`).
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "off" => Self::Off,
            "accept" | "target-accept" | "target_accept" => {
                Self::target_acceptance(DEFAULT_TARGET_ACCEPT)
            }
            "budget" | "eval-budget" | "eval_budget" => Self::eval_budget(),
            other => bail!(
                "unknown control policy {other:?} (expected off | target-accept | eval-budget)"
            ),
        })
    }

    /// Whether adaptation is disabled.
    pub fn is_off(&self) -> bool {
        matches!(self, Self::Off)
    }

    /// The review cadence (0 for [`ControlPolicy::Off`]).
    pub fn adapt_every(&self) -> u64 {
        match self {
            Self::Off => 0,
            Self::TargetAcceptance { adapt_every, .. } | Self::EvalBudget { adapt_every } => {
                *adapt_every
            }
        }
    }

    /// Replace the review cadence (no-op for [`ControlPolicy::Off`]).
    pub fn with_adapt_every(self, every: u64) -> Self {
        match self {
            Self::Off => Self::Off,
            Self::TargetAcceptance { target, band, .. } => Self::TargetAcceptance {
                target,
                band,
                adapt_every: every,
            },
            Self::EvalBudget { .. } => Self::EvalBudget { adapt_every: every },
        }
    }

    /// Replace the acceptance target (no-op for other policies).
    pub fn with_target(self, target: f64) -> Self {
        match self {
            Self::TargetAcceptance {
                band, adapt_every, ..
            } => Self::TargetAcceptance {
                target,
                band,
                adapt_every,
            },
            other => other,
        }
    }

    /// Validate parameter ranges (called by `RunSpecBuilder::build`).
    pub fn validate(&self) -> Result<()> {
        match *self {
            Self::Off => {}
            Self::TargetAcceptance {
                target,
                band,
                adapt_every,
            } => {
                if !(target > 0.0 && target < 1.0) {
                    bail!("control target acceptance must be in (0, 1), got {target}");
                }
                if !(band > 0.0 && band < 1.0) {
                    bail!("control acceptance band must be in (0, 1), got {band}");
                }
                if adapt_every == 0 {
                    bail!("control adapt_every must be > 0");
                }
            }
            Self::EvalBudget { adapt_every } => {
                if adapt_every == 0 {
                    bail!("control adapt_every must be > 0");
                }
            }
        }
        Ok(())
    }
}

impl std::fmt::Display for ControlPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Off => write!(f, "off"),
            Self::TargetAcceptance {
                target,
                band,
                adapt_every,
            } => write!(
                f,
                "target-accept {target} ± {band} (review every {adapt_every})"
            ),
            Self::EvalBudget { adapt_every } => {
                write!(f, "eval-budget (review every {adapt_every})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_resolve() {
        assert_eq!(ControlPolicy::from_name("off").unwrap(), ControlPolicy::Off);
        assert!(matches!(
            ControlPolicy::from_name("target-accept").unwrap(),
            ControlPolicy::TargetAcceptance { .. }
        ));
        assert!(matches!(
            ControlPolicy::from_name("budget").unwrap(),
            ControlPolicy::EvalBudget { .. }
        ));
        assert!(ControlPolicy::from_name("nope").is_err());
    }

    #[test]
    fn validation_catches_bad_ranges() {
        assert!(ControlPolicy::Off.validate().is_ok());
        assert!(ControlPolicy::target_acceptance(0.7).validate().is_ok());
        assert!(ControlPolicy::target_acceptance(1.5).validate().is_err());
        assert!(ControlPolicy::target_acceptance(0.0).validate().is_err());
        assert!(ControlPolicy::target_acceptance(0.7)
            .with_adapt_every(0)
            .validate()
            .is_err());
        assert!(ControlPolicy::eval_budget().with_adapt_every(0).validate().is_err());
    }

    #[test]
    fn setters_rewrite_fields() {
        let p = ControlPolicy::target_acceptance(0.5)
            .with_target(0.8)
            .with_adapt_every(250);
        match p {
            ControlPolicy::TargetAcceptance {
                target,
                adapt_every,
                ..
            } => {
                assert_eq!(target, 0.8);
                assert_eq!(adapt_every, 250);
            }
            _ => panic!("wrong variant"),
        }
        assert!(ControlPolicy::Off.with_adapt_every(9).is_off());
        assert_eq!(ControlPolicy::Off.adapt_every(), 0);
    }
}
