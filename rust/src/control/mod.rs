//! Adaptive sampling control.
//!
//! The minibatch samplers all hinge on hyperparameters the paper sets
//! a-priori from graph statistics — λ = Θ(L²) for MGPMH, λ = 2Ψ²/δ for
//! MIN-Gibbs (Lemma 2), a batch size B for Local Minibatch. Those
//! recipes need Ψ, L and a chosen slack δ up front; on a real model the
//! practical sweet spot (acceptance high enough to mix, minibatches
//! small enough to pay off) is easier to find *while sampling*.
//!
//! This module closes that loop. A [`ControlPolicy`] chosen at run
//! configuration time ([`crate::coordinator::RunSpecBuilder::control`])
//! makes the runner attach one [`Controller`] per chain. The controller
//! periodically reviews the chain's live [`crate::metrics::SamplerMetrics`]
//! — windowed acceptance rate, factor evals per effective sample — and
//! the recorded marginal-error trajectory, then retunes λ / B through
//! the [`crate::samplers::Sampler`] hyperparameter surface
//! (`hyperparams` / `set_hyperparams`). Retuning mid-run is sound for
//! the same reason the samplers are correct at any fixed λ: each step is
//! a Markov kernel with the right stationary distribution, and changing
//! λ between steps just composes different such kernels.
//!
//! When the error trajectory plateaus the controller freezes (no more
//! adjustments) and asks the runner for an early checkpoint, capturing
//! the tuned hyperparameters — which checkpoints persist, so `--resume`
//! picks up the tuned values instead of the originals.

mod controller;
mod policy;

pub use controller::{ControlAction, Controller, PlateauDetector};
pub use policy::{
    ControlPolicy, DEFAULT_ADAPT_EVERY, DEFAULT_BAND, DEFAULT_TARGET_ACCEPT,
};
