//! `mbgibbs` binary: the Layer-3 leader entrypoint.

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = mbgibbs::cli::run(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
