//! Multi-chain sampling coordinator.
//!
//! The coordinator owns process topology: it fans a workload out over
//! OS threads (one chain per thread, each with an independent split RNG
//! stream), drives per-chain samplers, streams samples into [`sink`]s,
//! writes [`checkpoint`]s, and aggregates a [`RunReport`].

pub mod checkpoint;
pub mod runner;
pub mod sink;

pub use checkpoint::Checkpoint;
pub use runner::{
    run_chains, run_chains_with_metrics, ChainReport, RunReport, RunSpec, RunSpecBuilder,
};
pub use sink::{EnergyTraceSink, MarginalTrajectorySink, SampleSink};
