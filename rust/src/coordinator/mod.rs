//! Multi-chain sampling coordinator.
//!
//! The coordinator owns process topology: it fans a workload out over
//! OS threads (one chain per thread, each with an independent split RNG
//! stream, optionally running within-chain parallel sweeps), drives
//! per-chain samplers, streams samples into [`sink`]s, writes
//! [`checkpoint`]s, and aggregates a [`RunReport`].

pub mod checkpoint;
pub mod runner;
pub mod sink;

pub use checkpoint::Checkpoint;
pub use runner::{run_chains, ChainReport, RunOptions, RunReport, RunSpec, RunSpecBuilder};
pub use sink::{EnergyTraceSink, MarginalTrajectorySink, SampleSink};
