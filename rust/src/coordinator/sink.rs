//! Sample sinks: streaming consumers of chain output.

use crate::analysis::MarginalEstimator;
use crate::graph::FactorGraph;

/// A streaming consumer of samples from one chain.
pub trait SampleSink: Send {
    /// Called after every sampler step with the current state.
    fn on_sample(&mut self, iter: u64, state: &[u16]);

    /// Called once when the chain finishes.
    fn on_finish(&mut self, _final_state: &[u16]) {}
}

/// Records the paper's Figure-1/2 metric: the running-marginal ℓ₂ error
/// vs uniform, checkpointed every `record_every` iterations.
pub struct MarginalTrajectorySink {
    estimator: MarginalEstimator,
    record_every: u64,
    /// (iteration, error) checkpoints.
    pub trajectory: Vec<(u64, f64)>,
}

impl MarginalTrajectorySink {
    /// New sink for `n` variables over domain `d`.
    pub fn new(n: usize, d: usize, record_every: u64) -> Self {
        Self {
            estimator: MarginalEstimator::new(n, d),
            record_every: record_every.max(1),
            trajectory: Vec::new(),
        }
    }

    /// Final marginal estimator (e.g. to compare chains).
    pub fn estimator(&self) -> &MarginalEstimator {
        &self.estimator
    }
}

impl SampleSink for MarginalTrajectorySink {
    fn on_sample(&mut self, iter: u64, state: &[u16]) {
        self.estimator.update(state);
        if iter % self.record_every == 0 {
            self.trajectory
                .push((iter, self.estimator.l2_error_vs_uniform()));
        }
    }

    fn on_finish(&mut self, _final_state: &[u16]) {
        self.trajectory.push((
            self.estimator.samples(),
            self.estimator.l2_error_vs_uniform(),
        ));
    }
}

/// Records a thinned trace of the total energy ζ(x) — handy for mixing
/// diagnostics (autocorrelation/ESS are computed on this series).
pub struct EnergyTraceSink<'g> {
    graph: &'g FactorGraph,
    every: u64,
    /// Thinned energy series.
    pub trace: Vec<f64>,
}

impl<'g> EnergyTraceSink<'g> {
    /// Record ζ(x) every `every` iterations.
    pub fn new(graph: &'g FactorGraph, every: u64) -> Self {
        Self {
            graph,
            every: every.max(1),
            trace: Vec::new(),
        }
    }
}

impl SampleSink for EnergyTraceSink<'_> {
    fn on_sample(&mut self, iter: u64, state: &[u16]) {
        if iter % self.every == 0 {
            self.trace.push(self.graph.total_energy(state));
        }
    }
}

/// Discards everything (benchmark baseline).
pub struct NullSink;

impl SampleSink for NullSink {
    fn on_sample(&mut self, _iter: u64, _state: &[u16]) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn marginal_sink_checkpoints() {
        let mut sink = MarginalTrajectorySink::new(2, 2, 10);
        for it in 0..35u64 {
            sink.on_sample(it, &[0, 1]);
        }
        sink.on_finish(&[0, 1]);
        // checkpoints at 0, 10, 20, 30 + final
        assert_eq!(sink.trajectory.len(), 5);
        assert!(sink.trajectory.iter().all(|&(_, e)| e.is_finite()));
    }

    #[test]
    fn energy_trace_thinned() {
        let g = models::tiny_random(3, 2, 1.0, 1);
        let mut sink = EnergyTraceSink::new(&g, 5);
        let state = vec![0u16; 3];
        for it in 0..20u64 {
            sink.on_sample(it, &state);
        }
        assert_eq!(sink.trace.len(), 4);
        let want = g.total_energy(&state);
        assert!(sink.trace.iter().all(|&e| (e - want).abs() < 1e-12));
    }
}
