//! Chain checkpointing: save/restore a chain's position so long runs can
//! resume after interruption.
//!
//! Format is a small self-describing text file (no serde in the offline
//! dependency set):
//!
//! ```text
//! mbgibbs-checkpoint v2
//! iter = 123456
//! seed = 42
//! chain = 0
//! factor_evals = 456789
//! accepted = 120000
//! proposed = 123456
//! rng_state = 1f2e3d4c...        (hex u128)
//! rng_inc = 5a6b7c8d...          (hex u128)
//! lambda = 25.9                  (tuned hyperparameters, where present)
//! lambda2 = 957.1
//! batch = 250
//! aux_energy = -1.25             (MIN-Gibbs ε / DoubleMIN ξ cache)
//! site_rngs = 1f:5a 2e:6b ...    (parallel runs: per-site state:inc)
//! state = 0 1 2 0 1 ...
//! ```
//!
//! The counter keys (`factor_evals`, `accepted`, `proposed`) are
//! cumulative totals at checkpoint time; they let a resumed run CONTINUE
//! its metric counters instead of resetting them. Everything after them
//! is v2: the PCG stream position (making `--resume` a bit-exact replay
//! of the uninterrupted run), the possibly-controller-tuned
//! hyperparameters, and the augmented-space energy cache. All of it is
//! optional on parse, so v1 files still load — they just keep the old
//! restart-from-seed resume behavior.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::samplers::Hyperparams;

/// A point-in-time snapshot of one chain.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Iterations completed.
    pub iter: u64,
    /// Master seed the run started from.
    pub seed: u64,
    /// Chain index.
    pub chain: usize,
    /// Cumulative factor evaluations at checkpoint time.
    pub factor_evals: u64,
    /// Cumulative MH acceptances at checkpoint time.
    pub accepted: u64,
    /// Cumulative MH proposals at checkpoint time (0 for Gibbs-type).
    pub proposed: u64,
    /// PCG stream position `(state, inc)` at checkpoint time; `None` in
    /// legacy files (resume then restarts the stream from the seed).
    pub rng: Option<(u128, u128)>,
    /// Hyperparameters (possibly tuned by the adaptive controller) at
    /// checkpoint time; empty for samplers with no knobs or legacy files.
    pub hyperparams: Hyperparams,
    /// Augmented-space energy cache (MIN-Gibbs ε / DoubleMIN ξ).
    pub aux_energy: Option<f64>,
    /// Per-site PCG stream positions, one `(state, inc)` pair per
    /// variable — written by parallel (`workers > 0`) runs, where
    /// randomness is keyed to sites rather than a single chain stream.
    /// `None` for serial runs and legacy files.
    pub site_rngs: Option<Vec<(u128, u128)>>,
    /// Variable assignment.
    pub state: Vec<u16>,
}

impl Checkpoint {
    /// Serialize to the text format.
    pub fn to_text(&self) -> String {
        let state: Vec<String> = self.state.iter().map(|v| v.to_string()).collect();
        let mut out = format!(
            "mbgibbs-checkpoint v2\niter = {}\nseed = {}\nchain = {}\n\
             factor_evals = {}\naccepted = {}\nproposed = {}\n",
            self.iter, self.seed, self.chain, self.factor_evals, self.accepted, self.proposed,
        );
        if let Some((s, inc)) = self.rng {
            out.push_str(&format!("rng_state = {s:x}\nrng_inc = {inc:x}\n"));
        }
        if let Some(l) = self.hyperparams.lambda {
            out.push_str(&format!("lambda = {l}\n"));
        }
        if let Some(l2) = self.hyperparams.lambda2 {
            out.push_str(&format!("lambda2 = {l2}\n"));
        }
        if let Some(b) = self.hyperparams.batch {
            out.push_str(&format!("batch = {b}\n"));
        }
        if let Some(e) = self.aux_energy {
            out.push_str(&format!("aux_energy = {e}\n"));
        }
        if let Some(parts) = &self.site_rngs {
            let toks: Vec<String> = parts.iter().map(|(s, i)| format!("{s:x}:{i:x}")).collect();
            out.push_str(&format!("site_rngs = {}\n", toks.join(" ")));
        }
        out.push_str(&format!("state = {}\n", state.join(" ")));
        out
    }

    /// Parse from the text format (v1 or v2).
    pub fn from_text(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        if header != "mbgibbs-checkpoint v1" && header != "mbgibbs-checkpoint v2" {
            bail!("bad checkpoint header: {header:?}");
        }
        let (mut iter, mut seed, mut chain, mut state) = (None, None, None, None);
        let (mut factor_evals, mut accepted, mut proposed) = (0u64, 0u64, 0u64);
        let (mut rng_state, mut rng_inc) = (None, None);
        let mut hyperparams = Hyperparams::default();
        let mut aux_energy = None;
        let mut site_rngs = None;
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("bad checkpoint line: {line:?}"))?;
            let value = value.trim();
            match key.trim() {
                "iter" => iter = Some(value.parse::<u64>()?),
                "seed" => seed = Some(value.parse::<u64>()?),
                "chain" => chain = Some(value.parse::<usize>()?),
                "factor_evals" => factor_evals = value.parse::<u64>()?,
                "accepted" => accepted = value.parse::<u64>()?,
                "proposed" => proposed = value.parse::<u64>()?,
                "rng_state" => {
                    rng_state = Some(
                        u128::from_str_radix(value, 16).context("bad rng_state (hex u128)")?,
                    )
                }
                "rng_inc" => {
                    rng_inc =
                        Some(u128::from_str_radix(value, 16).context("bad rng_inc (hex u128)")?)
                }
                "lambda" => hyperparams.lambda = Some(value.parse::<f64>()?),
                "lambda2" => hyperparams.lambda2 = Some(value.parse::<f64>()?),
                "batch" => hyperparams.batch = Some(value.parse::<usize>()?),
                "aux_energy" => aux_energy = Some(value.parse::<f64>()?),
                "site_rngs" => {
                    let parts: Result<Vec<(u128, u128)>> = value
                        .split_whitespace()
                        .map(|tok| {
                            let (s, i) = tok
                                .split_once(':')
                                .with_context(|| format!("bad site_rngs token {tok:?}"))?;
                            Ok((
                                u128::from_str_radix(s, 16)
                                    .context("bad site_rngs state (hex u128)")?,
                                u128::from_str_radix(i, 16)
                                    .context("bad site_rngs inc (hex u128)")?,
                            ))
                        })
                        .collect();
                    site_rngs = Some(parts?);
                }
                "state" => {
                    let vs: Result<Vec<u16>, _> =
                        value.split_whitespace().map(|t| t.parse::<u16>()).collect();
                    state = Some(vs?);
                }
                other => bail!("unknown checkpoint key {other:?}"),
            }
        }
        let rng = match (rng_state, rng_inc) {
            (Some(s), Some(i)) => Some((s, i)),
            (None, None) => None,
            _ => bail!("checkpoint has only one of rng_state / rng_inc"),
        };
        Ok(Self {
            iter: iter.context("missing iter")?,
            seed: seed.context("missing seed")?,
            chain: chain.context("missing chain")?,
            factor_evals,
            accepted,
            proposed,
            rng,
            hyperparams,
            aux_energy,
            site_rngs,
            state: state.context("missing state")?,
        })
    }

    /// Write atomically (tmp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_text())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            iter: 12345,
            seed: 42,
            chain: 3,
            factor_evals: 987_654,
            accepted: 11_000,
            proposed: 12_345,
            rng: Some(((0x0123_4567_89ab_cdef_u128 << 64) | 42, (7u128 << 64) | 0x55)),
            hyperparams: Hyperparams {
                lambda: Some(25.875),
                lambda2: Some(957.1),
                batch: None,
            },
            aux_energy: Some(-1.25),
            site_rngs: None,
            state: vec![0, 1, 2, 9, 0],
        }
    }

    #[test]
    fn text_roundtrip() {
        let c = sample();
        let parsed = Checkpoint::from_text(&c.to_text()).unwrap();
        assert_eq!(c, parsed);
    }

    /// Exact round trip for f64 values that are not dyadic-friendly:
    /// Rust's `Display` emits the shortest string that parses back to the
    /// identical bits.
    #[test]
    fn f64_values_roundtrip_bitexact() {
        let mut c = sample();
        c.hyperparams.lambda = Some(1.0 / 3.0 * 77.7);
        c.aux_energy = Some(-0.1 - 0.2);
        let parsed = Checkpoint::from_text(&c.to_text()).unwrap();
        assert_eq!(
            parsed.hyperparams.lambda.unwrap().to_bits(),
            c.hyperparams.lambda.unwrap().to_bits()
        );
        assert_eq!(
            parsed.aux_energy.unwrap().to_bits(),
            c.aux_energy.unwrap().to_bits()
        );
    }

    /// Parallel checkpoints carry one stream position per site.
    #[test]
    fn site_rngs_roundtrip() {
        let mut c = sample();
        c.site_rngs = Some(vec![
            (u128::MAX, 1),
            (0, u128::MAX),
            ((0xdead_beef_u128 << 64) | 0x1234, 0x5555),
        ]);
        let parsed = Checkpoint::from_text(&c.to_text()).unwrap();
        assert_eq!(c, parsed);
    }

    #[test]
    fn rejects_malformed_site_rngs() {
        let base = "mbgibbs-checkpoint v2\niter = 1\nseed = 2\nchain = 0\n";
        for bad in ["site_rngs = ff", "site_rngs = ff:zz", "site_rngs = ff:1 3"] {
            let text = format!("{base}{bad}\nstate = 0 1\n");
            assert!(
                Checkpoint::from_text(&text).is_err(),
                "accepted malformed line {bad:?}"
            );
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("mbgibbs_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Checkpoint::from_text("not a checkpoint").is_err());
        assert!(Checkpoint::from_text("mbgibbs-checkpoint v3\niter = 1\n").is_err());
    }

    /// Pre-observability v1 files (no counter keys) still load, with the
    /// counters defaulting to zero and no v2 extras.
    #[test]
    fn loads_legacy_files_without_counters() {
        let text = "mbgibbs-checkpoint v1\niter = 7\nseed = 2\nchain = 1\nstate = 0 1\n";
        let c = Checkpoint::from_text(text).unwrap();
        assert_eq!(c.iter, 7);
        assert_eq!(c.factor_evals, 0);
        assert_eq!(c.accepted, 0);
        assert_eq!(c.proposed, 0);
        assert_eq!(c.rng, None);
        assert!(c.hyperparams.is_empty());
        assert_eq!(c.aux_energy, None);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Checkpoint::from_text("mbgibbs-checkpoint v1\niter = 1\n").is_err());
    }

    #[test]
    fn rejects_garbage_state() {
        let text = "mbgibbs-checkpoint v1\niter = 1\nseed = 2\nchain = 0\nstate = 0 x 1\n";
        assert!(Checkpoint::from_text(text).is_err());
    }

    /// rng_state without rng_inc is a corrupt stream position, not a
    /// silently-degraded one.
    #[test]
    fn rejects_partial_rng_position() {
        let text = "mbgibbs-checkpoint v2\niter = 1\nseed = 2\nchain = 0\n\
                    rng_state = ff\nstate = 0 1\n";
        assert!(Checkpoint::from_text(text).is_err());
    }
}
