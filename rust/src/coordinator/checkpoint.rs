//! Chain checkpointing: save/restore a chain's position so long runs can
//! resume after interruption.
//!
//! Format is a small self-describing text file (no serde in the offline
//! dependency set):
//!
//! ```text
//! mbgibbs-checkpoint v1
//! iter = 123456
//! seed = 42
//! chain = 0
//! factor_evals = 456789
//! accepted = 120000
//! proposed = 123456
//! state = 0 1 2 0 1 ...
//! ```
//!
//! The counter keys (`factor_evals`, `accepted`, `proposed`) are
//! cumulative totals at checkpoint time; they let a resumed run CONTINUE
//! its metric counters instead of resetting them. They are optional on
//! parse (default 0) so pre-observability v1 files still load.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// A point-in-time snapshot of one chain.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Iterations completed.
    pub iter: u64,
    /// Master seed the run started from.
    pub seed: u64,
    /// Chain index.
    pub chain: usize,
    /// Cumulative factor evaluations at checkpoint time.
    pub factor_evals: u64,
    /// Cumulative MH acceptances at checkpoint time.
    pub accepted: u64,
    /// Cumulative MH proposals at checkpoint time (0 for Gibbs-type).
    pub proposed: u64,
    /// Variable assignment.
    pub state: Vec<u16>,
}

impl Checkpoint {
    /// Serialize to the text format.
    pub fn to_text(&self) -> String {
        let state: Vec<String> = self.state.iter().map(|v| v.to_string()).collect();
        format!(
            "mbgibbs-checkpoint v1\niter = {}\nseed = {}\nchain = {}\n\
             factor_evals = {}\naccepted = {}\nproposed = {}\nstate = {}\n",
            self.iter,
            self.seed,
            self.chain,
            self.factor_evals,
            self.accepted,
            self.proposed,
            state.join(" ")
        )
    }

    /// Parse from the text format.
    pub fn from_text(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        if header != "mbgibbs-checkpoint v1" {
            bail!("bad checkpoint header: {header:?}");
        }
        let (mut iter, mut seed, mut chain, mut state) = (None, None, None, None);
        let (mut factor_evals, mut accepted, mut proposed) = (0u64, 0u64, 0u64);
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("bad checkpoint line: {line:?}"))?;
            match key.trim() {
                "iter" => iter = Some(value.trim().parse::<u64>()?),
                "seed" => seed = Some(value.trim().parse::<u64>()?),
                "chain" => chain = Some(value.trim().parse::<usize>()?),
                "factor_evals" => factor_evals = value.trim().parse::<u64>()?,
                "accepted" => accepted = value.trim().parse::<u64>()?,
                "proposed" => proposed = value.trim().parse::<u64>()?,
                "state" => {
                    let vs: Result<Vec<u16>, _> =
                        value.split_whitespace().map(|t| t.parse::<u16>()).collect();
                    state = Some(vs?);
                }
                other => bail!("unknown checkpoint key {other:?}"),
            }
        }
        Ok(Self {
            iter: iter.context("missing iter")?,
            seed: seed.context("missing seed")?,
            chain: chain.context("missing chain")?,
            factor_evals,
            accepted,
            proposed,
            state: state.context("missing state")?,
        })
    }

    /// Write atomically (tmp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_text())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            iter: 12345,
            seed: 42,
            chain: 3,
            factor_evals: 987_654,
            accepted: 11_000,
            proposed: 12_345,
            state: vec![0, 1, 2, 9, 0],
        }
    }

    #[test]
    fn text_roundtrip() {
        let c = sample();
        let parsed = Checkpoint::from_text(&c.to_text()).unwrap();
        assert_eq!(c, parsed);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("mbgibbs_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Checkpoint::from_text("not a checkpoint").is_err());
    }

    /// Pre-observability v1 files (no counter keys) still load, with the
    /// counters defaulting to zero.
    #[test]
    fn loads_legacy_files_without_counters() {
        let text = "mbgibbs-checkpoint v1\niter = 7\nseed = 2\nchain = 1\nstate = 0 1\n";
        let c = Checkpoint::from_text(text).unwrap();
        assert_eq!(c.iter, 7);
        assert_eq!(c.factor_evals, 0);
        assert_eq!(c.accepted, 0);
        assert_eq!(c.proposed, 0);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Checkpoint::from_text("mbgibbs-checkpoint v1\niter = 1\n").is_err());
    }

    #[test]
    fn rejects_garbage_state() {
        let text = "mbgibbs-checkpoint v1\niter = 1\nseed = 2\nchain = 0\nstate = 0 x 1\n";
        assert!(Checkpoint::from_text(text).is_err());
    }
}
