//! The chain runner: fan out chains over threads, aggregate reports.
//!
//! Observability: every run attaches a [`MetricsHub`]; each chain
//! registers a [`SamplerMetrics`] family labeled `{chain, sampler}` and a
//! per-chain step-latency histogram (sampled 1-in-16 to amortize clock
//! reads). The final [`RunReport`] carries a [`Snapshot`] of everything.
//!
//! Control: when the spec carries a non-[`ControlPolicy::Off`] policy,
//! each chain also gets a [`Controller`] that periodically reviews the
//! live metrics and error trajectory and retunes the sampler's λ / B
//! (see [`crate::control`]).
//!
//! Parallelism: chains always run on their own threads; with
//! `workers > 0` each chain additionally runs *within-chain* parallel
//! sweeps on the chromatic engine ([`crate::runtime::parallel`]) —
//! site-local samplers only, control off, one RNG stream per site so
//! results are identical for any worker count.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::bench::workload::SamplerSpec;
use crate::control::{ControlPolicy, Controller};
use crate::graph::FactorGraph;
use crate::metrics::trace::{EventKind, TraceBuffer, TraceEvent};
use crate::metrics::{labeled, MetricsHub, SamplerMetrics, Snapshot};
use crate::rng::Pcg64;
use crate::runtime::parallel::ChromaticSweepEngine;
use crate::samplers::Sampler;

use super::checkpoint::Checkpoint;
use super::sink::{EnergyTraceSink, MarginalTrajectorySink};

/// What to run. Construct with [`RunSpec::builder`]; the fields stay
/// public for reading (reports, figure harness, tests).
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Sampler to instantiate per chain.
    pub sampler: SamplerSpec,
    /// Iterations per chain.
    pub iters: u64,
    /// Number of chains (threads).
    pub chains: usize,
    /// Master seed; chain k gets an independent split stream.
    pub seed: u64,
    /// Marginal-error checkpoint cadence.
    pub record_every: u64,
    /// Initial state: `None` = all zeros (the paper's unmixed start).
    pub init: Option<Vec<u16>>,
    /// If set, write a resumable checkpoint per chain every
    /// `checkpoint_every` iterations into this directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint cadence (iterations); 0 disables periodic checkpoints.
    pub checkpoint_every: u64,
    /// Resume from `checkpoint_dir/chain<k>.ckpt` where present: the
    /// chain restarts at the saved iteration/state, metric counters
    /// CONTINUE from the saved totals, the PCG stream is restored to its
    /// exact saved position (making the resumed run a bit-exact replay of
    /// the uninterrupted one), and controller-tuned hyperparameters are
    /// reapplied. Legacy v1 checkpoints carry no stream position; they
    /// keep the old restart-from-seed behavior (statistically fine, not
    /// bit-exact).
    pub resume: bool,
    /// Emit a progress line to stderr every this many iterations per
    /// chain; 0 disables.
    pub progress_every: u64,
    /// Per-chain trace ring-buffer capacity in events; 0 disables
    /// tracing entirely (nothing is allocated).
    pub trace_capacity: usize,
    /// Adaptive-control policy; [`ControlPolicy::Off`] (default) runs
    /// hyperparameters exactly as configured.
    pub control: ControlPolicy,
    /// Within-chain parallel workers; 0 (default) is the serial
    /// random-scan path. `workers >= 1` switches the chain to chromatic
    /// systematic sweeps ([`crate::runtime::parallel`]); results are
    /// identical for every worker count ≥ 1, so pick by core budget.
    pub workers: usize,
}

impl RunSpec {
    fn defaults(sampler: SamplerSpec) -> Self {
        Self {
            sampler,
            iters: 1_000_000,
            chains: 1,
            seed: 42,
            record_every: 10_000,
            init: None,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            progress_every: 0,
            trace_capacity: 0,
            control: ControlPolicy::Off,
            workers: 0,
        }
    }

    /// Start building a run spec: 1 chain, 10⁶ iterations, the paper's
    /// unmixed all-zeros init, control off.
    pub fn builder(sampler: SamplerSpec) -> RunSpecBuilder {
        RunSpecBuilder {
            spec: Self::defaults(sampler),
        }
    }

}

/// Fluent builder for [`RunSpec`]; [`RunSpecBuilder::build`] validates
/// the combination before it reaches the runner.
#[derive(Clone, Debug)]
pub struct RunSpecBuilder {
    spec: RunSpec,
}

impl RunSpecBuilder {
    /// Iterations per chain.
    pub fn iters(mut self, iters: u64) -> Self {
        self.spec.iters = iters;
        self
    }

    /// Number of chains (threads).
    pub fn chains(mut self, chains: usize) -> Self {
        self.spec.chains = chains;
        self
    }

    /// Master seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    /// Marginal-error recording cadence.
    pub fn record_every(mut self, every: u64) -> Self {
        self.spec.record_every = every;
        self
    }

    /// Explicit initial state (default: all zeros).
    pub fn init(mut self, init: Vec<u16>) -> Self {
        self.spec.init = Some(init);
        self
    }

    /// Checkpoint directory (enables `checkpoint_every` / `resume`).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spec.checkpoint_dir = Some(dir.into());
        self
    }

    /// Periodic checkpoint cadence in iterations (0 disables).
    pub fn checkpoint_every(mut self, every: u64) -> Self {
        self.spec.checkpoint_every = every;
        self
    }

    /// Resume from checkpoints in `checkpoint_dir`.
    pub fn resume(mut self, resume: bool) -> Self {
        self.spec.resume = resume;
        self
    }

    /// Progress-line cadence in iterations (0 disables).
    pub fn progress_every(mut self, every: u64) -> Self {
        self.spec.progress_every = every;
        self
    }

    /// Per-chain trace ring-buffer capacity (0 disables tracing).
    pub fn trace_capacity(mut self, capacity: usize) -> Self {
        self.spec.trace_capacity = capacity;
        self
    }

    /// Adaptive-control policy (default [`ControlPolicy::Off`]).
    pub fn control(mut self, policy: ControlPolicy) -> Self {
        self.spec.control = policy;
        self
    }

    /// Within-chain parallel workers (default 0 = serial random scan).
    /// Requires a site-local sampler (Gibbs, Local, MGPMH) and control
    /// off; see [`crate::runtime::parallel`] for the determinism
    /// contract.
    pub fn workers(mut self, workers: usize) -> Self {
        self.spec.workers = workers;
        self
    }

    /// Validate and produce the [`RunSpec`].
    pub fn build(self) -> Result<RunSpec> {
        let s = &self.spec;
        if s.chains == 0 {
            bail!("run spec needs at least one chain");
        }
        if s.iters == 0 {
            bail!("run spec needs at least one iteration");
        }
        if s.record_every == 0 {
            bail!("record_every must be > 0");
        }
        if s.resume && s.checkpoint_dir.is_none() {
            bail!("resume requires a checkpoint_dir");
        }
        if s.checkpoint_every > 0 && s.checkpoint_dir.is_none() {
            bail!("checkpoint_every requires a checkpoint_dir");
        }
        s.control.validate()?;
        if s.workers > 0 {
            if !s.sampler.supports_parallel() {
                bail!(
                    "workers > 0 needs a site-local sampler (Gibbs, Local, MGPMH); \
                     {:?} carries global augmented-space state",
                    s.sampler
                );
            }
            if s.control != ControlPolicy::Off {
                bail!(
                    "adaptive control is not supported with workers > 0; \
                     tune serially, then resume the checkpoint in parallel"
                );
            }
        }
        Ok(self.spec)
    }
}

/// Per-chain results.
#[derive(Clone, Debug)]
pub struct ChainReport {
    /// Chain index.
    pub chain: usize,
    /// (iteration, running ℓ₂ marginal error vs uniform) checkpoints.
    pub trajectory: Vec<(u64, f64)>,
    /// Final error.
    pub final_error: f64,
    /// Total factor evaluations (cumulative across resumes).
    pub factor_evals: u64,
    /// Accepted / proposed (1.0 for Gibbs-type samplers).
    pub acceptance: f64,
    /// Steps executed in THIS process (excludes pre-resume iterations).
    pub steps_executed: u64,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Final state.
    pub final_state: Vec<u16>,
    /// Retained trace events (empty unless `trace_capacity > 0`).
    pub trace: Vec<TraceEvent>,
    /// Thinned total-energy series ζ(x) sampled every `record_every`
    /// iterations — the scalar the cross-chain diagnostics run on.
    pub energy_trace: Vec<f64>,
}

/// Aggregated results.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Per-chain reports.
    pub chains: Vec<ChainReport>,
    /// Wall-clock aggregate throughput: every step executed in this
    /// process divided by the elapsed time of the whole fan-out — what a
    /// stopwatch on the run observes. Chains that finish early idle
    /// their thread, so this is ≤ chains × per-chain throughput.
    pub steps_per_sec: f64,
    /// Mean single-chain throughput: each chain's executed steps over
    /// its own busy time, averaged — the per-thread sampler speed,
    /// independent of fan-out skew.
    pub per_chain_steps_per_sec: f64,
    /// Mean factor evaluations per iteration.
    pub evals_per_iter: f64,
    /// Cross-chain Gelman–Rubin R̂ on the thinned energy series
    /// (`Some` with ≥ 2 chains and ≥ 2 recorded points per chain;
    /// traces are truncated to the shortest chain so mixed-resume runs
    /// still diagnose). R̂ ≈ 1 indicates the chains agree.
    pub rhat: Option<f64>,
    /// Pooled effective sample size: Σ over chains of n/τ on the same
    /// thinned energy series (`Some` when every chain recorded ≥ 2
    /// points).
    pub pooled_ess: Option<f64>,
    /// End-of-run snapshot of every metric the run touched.
    pub metrics: Snapshot,
}

impl RunReport {
    /// Mean final error across chains.
    pub fn mean_final_error(&self) -> f64 {
        self.chains.iter().map(|c| c.final_error).sum::<f64>() / self.chains.len() as f64
    }
}

/// Cross-chain convergence diagnostics on the thinned energy traces:
/// (R̂, pooled ESS) per the field docs on [`RunReport`].
pub(crate) fn energy_diagnostics(chains: &[ChainReport]) -> (Option<f64>, Option<f64>) {
    let traces: Vec<&[f64]> = chains.iter().map(|c| c.energy_trace.as_slice()).collect();
    crate::analysis::diagnostics::cross_chain_diagnostics(&traces)
}

/// Caller-side options orthogonal to *what* runs (that is [`RunSpec`]'s
/// job): today, whose metrics hub to record into.
#[derive(Clone, Default)]
pub struct RunOptions {
    /// Externally owned metrics hub — lets the caller watch the
    /// `sampler_*{chain="k",...}` counter families live from another
    /// thread while the run progresses (e.g. the CLI's periodic
    /// `--metrics-every` flusher). `None` gives the run a private hub;
    /// its end-of-run snapshot still lands in [`RunReport::metrics`].
    pub hub: Option<Arc<MetricsHub>>,
}

impl RunOptions {
    /// Record into an externally owned hub.
    pub fn with_hub(hub: Arc<MetricsHub>) -> Self {
        Self { hub: Some(hub) }
    }
}

/// Run `spec.chains` independent chains in parallel threads.
pub fn run_chains(graph: &FactorGraph, spec: &RunSpec, opts: &RunOptions) -> RunReport {
    let hub = opts
        .hub
        .clone()
        .unwrap_or_else(|| Arc::new(MetricsHub::new()));
    let mut master = Pcg64::seeded(spec.seed);
    let streams: Vec<Pcg64> = (0..spec.chains).map(|k| master.split(k as u64)).collect();

    let wall = Instant::now();
    let reports: Vec<ChainReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = streams
            .into_iter()
            .enumerate()
            .map(|(k, rng)| {
                let hub = hub.clone();
                scope.spawn(move || {
                    if spec.workers > 0 {
                        run_one_chain_parallel(graph, spec, k, rng, &hub)
                    } else {
                        run_one_chain(graph, spec, k, rng, &hub)
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall_secs = wall.elapsed().as_secs_f64();

    let executed_steps: u64 = reports.iter().map(|r| r.steps_executed).sum();
    let logical_steps = (spec.iters * spec.chains as u64).max(1);
    let total_evals: u64 = reports.iter().map(|r| r.factor_evals).sum();
    let per_chain_steps_per_sec = reports
        .iter()
        .map(|r| r.steps_executed as f64 / r.seconds.max(1e-12))
        .sum::<f64>()
        / reports.len() as f64;
    let (rhat, pooled_ess) = energy_diagnostics(&reports);
    RunReport {
        steps_per_sec: executed_steps as f64 / wall_secs.max(1e-12),
        per_chain_steps_per_sec,
        evals_per_iter: total_evals as f64 / logical_steps as f64,
        chains: reports,
        rhat,
        pooled_ess,
        metrics: hub.snapshot(),
    }
}

/// Record a step-latency sample (and a `Step` trace event) once every
/// this many iterations; amortizes the two `Instant::now()` reads to
/// keep the instrumented step path within the overhead budget.
const LATENCY_SAMPLE: u64 = 16;

/// Write a v2 checkpoint capturing the full chain position: state,
/// cumulative counters, the exact PCG stream position, and the sampler's
/// current (possibly controller-tuned) hyperparameters and energy cache.
#[allow(clippy::too_many_arguments)]
fn save_checkpoint(
    dir: &Path,
    spec: &RunSpec,
    k: usize,
    iter: u64,
    state: &[u16],
    m: &SamplerMetrics,
    rng: &Pcg64,
    site_rngs: Option<Vec<(u128, u128)>>,
    sampler: &dyn Sampler,
) {
    let _ = std::fs::create_dir_all(dir);
    let ckpt = Checkpoint {
        iter,
        seed: spec.seed,
        chain: k,
        factor_evals: m.factor_evals.get(),
        accepted: m.accepts.get(),
        proposed: m.proposals.get(),
        rng: Some(rng.state_parts()),
        hyperparams: sampler.hyperparams(),
        aux_energy: sampler.aux_energy(),
        site_rngs,
        state: state.to_vec(),
    };
    ckpt.save(&dir.join(format!("chain{k}.ckpt")))
        .expect("checkpoint write failed");
}

fn run_one_chain(
    graph: &FactorGraph,
    spec: &RunSpec,
    k: usize,
    mut rng: Pcg64,
    hub: &MetricsHub,
) -> ChainReport {
    let n = graph.n();
    let d = graph.domain_size() as usize;
    let mut state = spec.init.clone().unwrap_or_else(|| vec![0u16; n]);
    assert_eq!(state.len(), n, "init state has wrong length");
    let mut sampler = spec.sampler.build(graph);

    let chain_label = k.to_string();
    let m = SamplerMetrics::register(
        hub,
        &[("chain", &chain_label), ("sampler", sampler.name())],
    );
    let latency = hub.latency(&labeled("chain_step_latency_ns", &[("chain", &chain_label)]));
    let mut trace_buf = TraceBuffer::new(k as u32, spec.trace_capacity);

    // Resume: adopt the checkpointed position and seed the metric
    // counters with the saved cumulative totals so observability counts
    // the whole logical run, not just this process. v2 checkpoints also
    // restore the PCG stream position (bit-exact continuation), tuned
    // hyperparameters, and the augmented-space energy cache.
    let mut start_iter = 0u64;
    let mut restored_aux = None;
    if spec.resume {
        if let Some(dir) = &spec.checkpoint_dir {
            let path = dir.join(format!("chain{k}.ckpt"));
            if path.exists() {
                let ckpt = Checkpoint::load(&path).expect("resume: unreadable checkpoint");
                assert_eq!(ckpt.seed, spec.seed, "resume: checkpoint seed mismatch");
                assert_eq!(ckpt.chain, k, "resume: checkpoint chain mismatch");
                assert_eq!(ckpt.state.len(), n, "resume: checkpoint state length mismatch");
                assert!(
                    ckpt.iter <= spec.iters,
                    "resume: checkpoint is past the requested iteration count"
                );
                state = ckpt.state;
                start_iter = ckpt.iter;
                m.steps.add(ckpt.iter);
                m.factor_evals.add(ckpt.factor_evals);
                m.accepts.add(ckpt.accepted);
                m.proposals.add(ckpt.proposed);
                if let Some((s, inc)) = ckpt.rng {
                    rng = Pcg64::from_state_parts(s, inc);
                }
                if !ckpt.hyperparams.is_empty() {
                    sampler.set_hyperparams(&ckpt.hyperparams);
                }
                restored_aux = ckpt.aux_energy;
            }
        }
    }
    sampler.attach_metrics(m.clone());
    sampler.reset(&state, &mut rng);
    if let Some(e) = restored_aux {
        sampler.restore_aux_energy(e);
    }

    let mut controller = Controller::new(&spec.control, hub, &chain_label, m.clone(), graph.stats());
    if let Some(c) = &controller {
        c.publish(sampler.as_ref());
    }

    let mut sink = MarginalTrajectorySink::new(n, d, spec.record_every);
    let mut energy_sink = EnergyTraceSink::new(graph, spec.record_every);
    let start = Instant::now();
    for it in start_iter..spec.iters {
        if it % LATENCY_SAMPLE == 0 {
            let t0 = Instant::now();
            let st = sampler.step(&mut state, &mut rng);
            latency.record(t0.elapsed());
            crate::trace_event!(trace_buf, EventKind::Step, it, st.factor_evals);
        } else {
            sampler.step(&mut state, &mut rng);
        }
        use super::sink::SampleSink;
        sink.on_sample(it, &state);
        energy_sink.on_sample(it, &state);
        if spec.progress_every > 0 && (it + 1) % spec.progress_every == 0 {
            let done = it + 1 - start_iter;
            let rate = done as f64 / start.elapsed().as_secs_f64().max(1e-9);
            eprintln!(
                "[mbgibbs] chain {k}: iter {}/{} ({rate:.0} steps/s, {} factor evals)",
                it + 1,
                spec.iters,
                m.factor_evals.get(),
            );
            crate::trace_event!(trace_buf, EventKind::Progress, it + 1, 0);
        }
        if let Some(c) = controller.as_mut() {
            if c.due(it + 1) {
                let action = c.review(it + 1, sampler.as_mut(), &sink.trajectory);
                if action.save_checkpoint {
                    if let Some(dir) = &spec.checkpoint_dir {
                        save_checkpoint(dir, spec, k, it + 1, &state, &m, &rng, None, sampler.as_ref());
                        crate::trace_event!(trace_buf, EventKind::Checkpoint, it + 1, 0);
                    }
                }
            }
        }
        if spec.checkpoint_every > 0 && (it + 1) % spec.checkpoint_every == 0 {
            if let Some(dir) = &spec.checkpoint_dir {
                save_checkpoint(dir, spec, k, it + 1, &state, &m, &rng, None, sampler.as_ref());
                crate::trace_event!(trace_buf, EventKind::Checkpoint, it + 1, 0);
            }
        }
    }
    {
        use super::sink::SampleSink;
        sink.on_finish(&state);
    }
    let seconds = start.elapsed().as_secs_f64();
    let final_error = sink.estimator().l2_error_vs_uniform();
    ChainReport {
        chain: k,
        trajectory: sink.trajectory,
        final_error,
        factor_evals: m.factor_evals.get(),
        acceptance: m.acceptance(),
        steps_executed: spec.iters - start_iter,
        seconds,
        final_state: state,
        trace: trace_buf.events_in_order(),
        energy_trace: energy_sink.trace,
    }
}

/// One chain on the chromatic sweep engine (`spec.workers >= 1`).
///
/// Differences from the serial path, all at sweep granularity because
/// intermediate states only materialize at color-class boundaries:
/// the marginal sink samples once per sweep (n site updates) instead of
/// once per step; progress lines and periodic checkpoints fire at the
/// first sweep boundary on or after each configured multiple; and
/// checkpoints persist every per-site stream position so `--resume`
/// replays bit-exactly. Step/eval counters keep per-site-update meaning
/// — the worker samplers share this chain's [`SamplerMetrics`].
fn run_one_chain_parallel(
    graph: &FactorGraph,
    spec: &RunSpec,
    k: usize,
    mut rng: Pcg64,
    hub: &MetricsHub,
) -> ChainReport {
    let n = graph.n();
    let d = graph.domain_size() as usize;
    let mut state = spec.init.clone().unwrap_or_else(|| vec![0u16; n]);
    assert_eq!(state.len(), n, "init state has wrong length");
    // The probe sampler never steps: it carries the name for metric
    // labels and the (possibly checkpoint-restored) hyperparameters for
    // checkpoint writes. The sampling instances live in the engine's
    // workers, one per thread, sharing `m`.
    let mut probe = spec.sampler.build(graph);

    let chain_label = k.to_string();
    let m = SamplerMetrics::register(hub, &[("chain", &chain_label), ("sampler", probe.name())]);
    let mut trace_buf = TraceBuffer::new(k as u32, spec.trace_capacity);

    let mut start_iter = 0u64;
    let mut saved_site_rngs: Option<Vec<(u128, u128)>> = None;
    if spec.resume {
        if let Some(dir) = &spec.checkpoint_dir {
            let path = dir.join(format!("chain{k}.ckpt"));
            if path.exists() {
                let ckpt = Checkpoint::load(&path).expect("resume: unreadable checkpoint");
                assert_eq!(ckpt.seed, spec.seed, "resume: checkpoint seed mismatch");
                assert_eq!(ckpt.chain, k, "resume: checkpoint chain mismatch");
                assert_eq!(ckpt.state.len(), n, "resume: checkpoint state length mismatch");
                assert!(
                    ckpt.iter <= spec.iters,
                    "resume: checkpoint is past the requested iteration count"
                );
                state = ckpt.state;
                start_iter = ckpt.iter;
                m.steps.add(ckpt.iter);
                m.factor_evals.add(ckpt.factor_evals);
                m.accepts.add(ckpt.accepted);
                m.proposals.add(ckpt.proposed);
                if !ckpt.hyperparams.is_empty() {
                    probe.set_hyperparams(&ckpt.hyperparams);
                }
                saved_site_rngs = ckpt.site_rngs;
            }
        }
    }

    let mut engine = ChromaticSweepEngine::new(
        graph,
        spec.sampler,
        spec.workers,
        &mut rng,
        m.clone(),
        hub,
        &chain_label,
    );
    engine.set_hyperparams(probe.hyperparams());
    if let Some(parts) = &saved_site_rngs {
        engine
            .restore_site_rngs(parts)
            .expect("resume: checkpoint site streams do not match this graph");
    }

    let mut sink = MarginalTrajectorySink::new(n, d, spec.record_every);
    let mut energy_sink = EnergyTraceSink::new(graph, spec.record_every);
    let start = Instant::now();
    // A boundary at `iter` fires cadence `every` if it is the first
    // boundary at or past a multiple of `every` since `prev`.
    let crossed = |prev: u64, iter: u64, every: u64| iter / every > prev / every;
    let mut prev_iter = start_iter;
    engine.run(&mut state, start_iter, spec.iters, &mut |ctx| {
        use super::sink::SampleSink;
        sink.on_sample(ctx.iter, ctx.state);
        energy_sink.on_sample(ctx.iter, ctx.state);
        if spec.progress_every > 0 && crossed(prev_iter, ctx.iter, spec.progress_every) {
            let done = ctx.iter - start_iter;
            let rate = done as f64 / start.elapsed().as_secs_f64().max(1e-9);
            eprintln!(
                "[mbgibbs] chain {k}: iter {}/{} ({rate:.0} steps/s, {} factor evals, {} workers)",
                ctx.iter,
                spec.iters,
                m.factor_evals.get(),
                spec.workers,
            );
            crate::trace_event!(trace_buf, EventKind::Progress, ctx.iter, 0);
        }
        if spec.checkpoint_every > 0 && crossed(prev_iter, ctx.iter, spec.checkpoint_every) {
            if let Some(dir) = &spec.checkpoint_dir {
                save_checkpoint(
                    dir,
                    spec,
                    k,
                    ctx.iter,
                    ctx.state,
                    &m,
                    &rng,
                    Some(ctx.site_rng_parts()),
                    probe.as_ref(),
                );
                crate::trace_event!(trace_buf, EventKind::Checkpoint, ctx.iter, 0);
            }
        }
        prev_iter = ctx.iter;
    });
    {
        use super::sink::SampleSink;
        sink.on_finish(&state);
    }
    let seconds = start.elapsed().as_secs_f64();
    let final_error = sink.estimator().l2_error_vs_uniform();
    ChainReport {
        chain: k,
        trajectory: sink.trajectory,
        final_error,
        factor_evals: m.factor_evals.get(),
        acceptance: m.acceptance(),
        steps_executed: spec.iters - start_iter,
        seconds,
        final_state: state,
        trace: trace_buf.events_in_order(),
        energy_trace: energy_sink.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    #[test]
    fn runs_multiple_chains() {
        let g = models::tiny_random(4, 3, 0.8, 5);
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(20_000)
            .chains(3)
            .record_every(5_000)
            .build()
            .unwrap();
        let report = run_chains(&g, &spec, &RunOptions::default());
        assert_eq!(report.chains.len(), 3);
        for c in &report.chains {
            assert!(c.final_error < 0.2, "chain {} error {}", c.chain, c.final_error);
            assert!(!c.trajectory.is_empty());
            assert_eq!(c.acceptance, 1.0);
            assert_eq!(c.steps_executed, 20_000);
        }
        assert!(report.steps_per_sec > 0.0);
        assert!(report.evals_per_iter > 0.0);
    }

    #[test]
    fn builder_validates_combinations() {
        let mk = || RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Generic));
        assert!(mk().build().is_ok());
        assert!(mk().chains(0).build().is_err());
        assert!(mk().iters(0).build().is_err());
        assert!(mk().record_every(0).build().is_err());
        assert!(mk().resume(true).build().is_err(), "resume needs a dir");
        assert!(mk().checkpoint_every(10).build().is_err(), "cadence needs a dir");
        assert!(mk()
            .checkpoint_dir("/tmp/x")
            .checkpoint_every(10)
            .resume(true)
            .build()
            .is_ok());
        assert!(mk()
            .control(ControlPolicy::target_acceptance(1.5))
            .build()
            .is_err());
        assert!(mk()
            .control(ControlPolicy::target_acceptance(0.6))
            .build()
            .is_ok());
    }

    /// The parallel engine only accepts combinations it can run
    /// correctly: site-local samplers, control off.
    #[test]
    fn builder_validates_parallel_combinations() {
        let ok = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .workers(4)
            .build();
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().workers, 4);
        assert!(
            RunSpec::builder(SamplerSpec::MinGibbs { lambda: 10.0 })
                .workers(2)
                .build()
                .is_err(),
            "MIN-Gibbs carries global cached ε; must be rejected"
        );
        assert!(
            RunSpec::builder(SamplerSpec::DoubleMin { lambda1: 4.0, lambda2: 16.0 })
                .workers(2)
                .build()
                .is_err(),
            "DoubleMIN carries global cached ξ; must be rejected"
        );
        assert!(
            RunSpec::builder(SamplerSpec::Mgpmh { lambda: 10.0 })
                .workers(2)
                .control(ControlPolicy::target_acceptance(0.6))
                .build()
                .is_err(),
            "adaptive control must be rejected with workers > 0"
        );
    }

    /// Dispatch through the public entry point: a parallel spec must
    /// produce worker-count-invariant results, flow `parallel_*` metrics
    /// into the report snapshot, and fill both throughput fields.
    #[test]
    fn parallel_workers_run_and_report() {
        let g = models::ising_multipartite(3, 6, 1.5);
        let n = g.n() as u64;
        let mk = |w: usize| {
            RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
                .iters(n * 50)
                .record_every(n * 10)
                .workers(w)
                .build()
                .unwrap()
        };
        let r1 = run_chains(&g, &mk(1), &RunOptions::default());
        let r4 = run_chains(&g, &mk(4), &RunOptions::default());
        assert_eq!(
            r1.chains[0].final_state, r4.chains[0].final_state,
            "worker count changed the chain"
        );
        assert_eq!(r4.chains[0].steps_executed, n * 50);
        assert!(r4.steps_per_sec > 0.0);
        assert!(r4.per_chain_steps_per_sec > 0.0);
        assert_eq!(
            r4.metrics.counter("parallel_sweeps_total{chain=\"0\"}"),
            Some(50)
        );
        assert_eq!(
            r4.metrics
                .counter("sampler_steps_total{chain=\"0\",sampler=\"gibbs\"}"),
            Some(n * 50)
        );
    }

    /// Multi-chain runs surface cross-chain R̂ and pooled ESS computed
    /// on the thinned energy traces.
    #[test]
    fn report_carries_convergence_diagnostics() {
        let g = models::tiny_random(4, 3, 0.8, 5);
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(20_000)
            .chains(3)
            .record_every(100)
            .build()
            .unwrap();
        let report = run_chains(&g, &spec, &RunOptions::default());
        for c in &report.chains {
            assert_eq!(c.energy_trace.len(), 200, "one ζ sample per record_every");
        }
        let rhat = report.rhat.expect("3 chains must produce an R̂");
        assert!(
            (rhat - 1.0).abs() < 0.25,
            "well-mixed tiny model should have R̂ near 1, got {rhat}"
        );
        let ess = report.pooled_ess.expect("pooled ESS must be present");
        assert!(ess > 3.0 && ess <= 600.0, "pooled ESS out of range: {ess}");

        // A single chain has no cross-chain R̂ but still reports ESS.
        let spec1 = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(5_000)
            .record_every(100)
            .build()
            .unwrap();
        let r1 = run_chains(&g, &spec1, &RunOptions::default());
        assert!(r1.rhat.is_none());
        assert!(r1.pooled_ess.is_some());
    }

    #[test]
    fn chains_use_distinct_streams() {
        let g = models::tiny_random(4, 2, 0.5, 6);
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Generic))
            .iters(500)
            .chains(2)
            .build()
            .unwrap();
        let report = run_chains(&g, &spec, &RunOptions::default());
        // Overwhelmingly the final states should differ.
        assert_ne!(
            report.chains[0].final_state, report.chains[1].final_state,
            "chains produced identical trajectories"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let g = models::tiny_random(3, 2, 0.5, 7);
        let spec = RunSpec::builder(SamplerSpec::Mgpmh { lambda: 3.0 })
            .iters(5_000)
            .chains(2)
            .build()
            .unwrap();
        let a = run_chains(&g, &spec, &RunOptions::default());
        let b = run_chains(&g, &spec, &RunOptions::default());
        for (ca, cb) in a.chains.iter().zip(b.chains.iter()) {
            assert_eq!(ca.final_state, cb.final_state);
            assert_eq!(ca.factor_evals, cb.factor_evals);
        }
    }

    #[test]
    fn periodic_checkpoints_written_and_loadable() {
        let g = models::tiny_random(3, 2, 0.5, 9);
        let dir = std::env::temp_dir().join(format!("mbgibbs_run_ckpt_{}", std::process::id()));
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(1_000)
            .chains(2)
            .checkpoint_dir(dir.clone())
            .checkpoint_every(400)
            .build()
            .unwrap();
        let report = run_chains(&g, &spec, &RunOptions::default());
        for k in 0..2 {
            let ckpt =
                crate::coordinator::Checkpoint::load(&dir.join(format!("chain{k}.ckpt")))
                    .unwrap();
            assert_eq!(ckpt.chain, k);
            assert_eq!(ckpt.iter, 800); // last multiple of 400 within 1000
            assert_eq!(ckpt.state.len(), 3);
            assert!(ckpt.factor_evals > 0, "checkpoint missing cumulative evals");
            assert!(ckpt.rng.is_some(), "v2 checkpoint must carry the stream position");
        }
        assert_eq!(report.chains.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_hub_sees_progress() {
        use std::sync::Arc;
        let g = models::tiny_random(3, 2, 0.5, 10);
        let hub = Arc::new(crate::metrics::MetricsHub::new());
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Generic))
            .iters(10_000)
            .build()
            .unwrap();
        let report = run_chains(&g, &spec, &RunOptions::with_hub(hub.clone()));
        let snap = hub.snapshot();
        let steps = snap
            .counter("sampler_steps_total{chain=\"0\",sampler=\"gibbs\"}")
            .unwrap();
        assert_eq!(steps, 10_000);
        let evals = snap
            .counter("sampler_factor_evals_total{chain=\"0\",sampler=\"gibbs\"}")
            .unwrap();
        assert!(evals > 0);
        assert_eq!(report.chains[0].factor_evals, evals);
        // Step latency histogram: 1-in-16 sampling over 10k steps.
        let lat = snap.histogram("chain_step_latency_ns{chain=\"0\"}").unwrap();
        assert_eq!(lat.count, 10_000 / LATENCY_SAMPLE);
        assert!(lat.p50 > 0.0);
        // And the run report embeds the same snapshot.
        assert_eq!(report.metrics.counter_family_sum("sampler_steps_total"), 10_000);
    }

    #[test]
    fn respects_custom_init() {
        let g = models::tiny_random(3, 3, 0.3, 8);
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(1)
            .init(vec![2, 2, 2])
            .build()
            .unwrap();
        let report = run_chains(&g, &spec, &RunOptions::default());
        // After one step only one variable may have changed.
        let diff = report.chains[0]
            .final_state
            .iter()
            .filter(|&&v| v != 2)
            .count();
        assert!(diff <= 1);
    }

    /// Write checkpoints, then resume on a fresh hub: the resumed run
    /// must pick up at the checkpointed iteration and CONTINUE the
    /// metric counters from the saved totals rather than resetting.
    #[test]
    fn resume_continues_metric_counters() {
        let g = models::tiny_random(3, 2, 0.5, 11);
        let dir = std::env::temp_dir().join(format!("mbgibbs_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(600)
            .checkpoint_dir(dir.clone())
            .checkpoint_every(300)
            .build()
            .unwrap();
        let first = run_chains(&g, &spec, &RunOptions::default());
        let evals_at_600 = first.chains[0].factor_evals;

        // Resume the same run with a higher target: counters continue.
        let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(1_000)
            .checkpoint_dir(dir.clone())
            .checkpoint_every(300)
            .resume(true)
            .build()
            .unwrap();
        let resumed = run_chains(&g, &spec, &RunOptions::default());
        let c = &resumed.chains[0];
        assert_eq!(c.steps_executed, 400, "should resume at iter 600");
        assert!(
            c.factor_evals > evals_at_600,
            "cumulative evals must grow past the checkpoint total"
        );
        let steps = resumed
            .metrics
            .counter("sampler_steps_total{chain=\"0\",sampler=\"gibbs\"}")
            .unwrap();
        assert_eq!(steps, 1_000, "steps counter must include pre-resume iterations");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Bit-exact resume: interrupt + resume must replay the EXACT same
    /// chain as the uninterrupted run — same final state, same eval
    /// count — because v2 checkpoints restore the PCG stream position
    /// and the MIN-Gibbs energy cache.
    #[test]
    fn resume_is_bit_exact_for_mingibbs() {
        let g = models::tiny_random(4, 3, 0.8, 12);
        let dir = std::env::temp_dir().join(format!("mbgibbs_bitexact_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        let uninterrupted = RunSpec::builder(SamplerSpec::MinGibbs { lambda: 40.0 })
            .iters(1_000)
            .build()
            .unwrap();
        let full = run_chains(&g, &uninterrupted, &RunOptions::default());

        let first_leg = RunSpec::builder(SamplerSpec::MinGibbs { lambda: 40.0 })
            .iters(600)
            .checkpoint_dir(dir.clone())
            .checkpoint_every(600)
            .build()
            .unwrap();
        run_chains(&g, &first_leg, &RunOptions::default());
        let second_leg = RunSpec::builder(SamplerSpec::MinGibbs { lambda: 40.0 })
            .iters(1_000)
            .checkpoint_dir(dir.clone())
            .resume(true)
            .build()
            .unwrap();
        let resumed = run_chains(&g, &second_leg, &RunOptions::default());

        assert_eq!(
            full.chains[0].final_state, resumed.chains[0].final_state,
            "resumed chain diverged from the uninterrupted run"
        );
        assert_eq!(
            full.chains[0].factor_evals, resumed.chains[0].factor_evals,
            "resumed chain did different work than the uninterrupted run"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// An adaptive run writes the tuned λ into its checkpoints, and a
    /// resume (control off) picks the tuned value back up.
    #[test]
    fn resume_restores_controller_tuned_lambda() {
        let g = models::tiny_random(4, 3, 0.8, 13);
        let dir = std::env::temp_dir().join(format!("mbgibbs_tuned_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        let spec = RunSpec::builder(SamplerSpec::Mgpmh { lambda: 500.0 })
            .iters(2_000)
            .control(ControlPolicy::target_acceptance(0.7).with_adapt_every(200))
            .checkpoint_dir(dir.clone())
            .checkpoint_every(2_000)
            .build()
            .unwrap();
        run_chains(&g, &spec, &RunOptions::default());
        let ckpt = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
        let tuned = ckpt.hyperparams.lambda.expect("checkpoint missing λ");
        assert!(tuned < 500.0, "controller should have shrunk λ, got {tuned}");

        let resumed_spec = RunSpec::builder(SamplerSpec::Mgpmh { lambda: 500.0 })
            .iters(2_500)
            .checkpoint_dir(dir.clone())
            .checkpoint_every(2_500)
            .resume(true)
            .build()
            .unwrap();
        run_chains(&g, &resumed_spec, &RunOptions::default());
        let after = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
        assert_eq!(after.iter, 2_500);
        assert_eq!(
            after.hyperparams.lambda.unwrap(),
            tuned,
            "resume must carry the tuned λ forward, not reset to the spec's"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
