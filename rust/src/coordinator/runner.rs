//! The chain runner: fan out chains over threads, aggregate reports.
//!
//! Observability: every run attaches a [`MetricsHub`]; each chain
//! registers a [`SamplerMetrics`] family labeled `{chain, sampler}` and a
//! per-chain step-latency histogram (sampled 1-in-16 to amortize clock
//! reads). The final [`RunReport`] carries a [`Snapshot`] of everything.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::bench::workload::SamplerSpec;
use crate::graph::FactorGraph;
use crate::metrics::trace::{EventKind, TraceBuffer, TraceEvent};
use crate::metrics::{labeled, MetricsHub, SamplerMetrics, Snapshot};
use crate::rng::Pcg64;

use super::checkpoint::Checkpoint;
use super::sink::MarginalTrajectorySink;

/// What to run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Sampler to instantiate per chain.
    pub sampler: SamplerSpec,
    /// Iterations per chain.
    pub iters: u64,
    /// Number of chains (threads).
    pub chains: usize,
    /// Master seed; chain k gets an independent split stream.
    pub seed: u64,
    /// Marginal-error checkpoint cadence.
    pub record_every: u64,
    /// Initial state: `None` = all zeros (the paper's unmixed start).
    pub init: Option<Vec<u16>>,
    /// If set, write a resumable checkpoint per chain every
    /// `checkpoint_every` iterations into this directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint cadence (iterations); 0 disables periodic checkpoints.
    pub checkpoint_every: u64,
    /// Resume from `checkpoint_dir/chain<k>.ckpt` where present: the
    /// chain restarts at the saved iteration/state and its metric
    /// counters CONTINUE from the saved totals. The RNG stream restarts
    /// from the master seed (statistically fine — the resumed chain is a
    /// valid chain — but not a bit-exact replay of the uninterrupted run).
    pub resume: bool,
    /// Emit a progress line to stderr every this many iterations per
    /// chain; 0 disables.
    pub progress_every: u64,
    /// Per-chain trace ring-buffer capacity in events; 0 disables
    /// tracing entirely (nothing is allocated).
    pub trace_capacity: usize,
}

impl RunSpec {
    /// Sensible defaults: 1 chain, 10⁶ iterations, paper's unmixed init.
    pub fn new(sampler: SamplerSpec) -> Self {
        Self {
            sampler,
            iters: 1_000_000,
            chains: 1,
            seed: 42,
            record_every: 10_000,
            init: None,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            progress_every: 0,
            trace_capacity: 0,
        }
    }
}

/// Per-chain results.
#[derive(Clone, Debug)]
pub struct ChainReport {
    /// Chain index.
    pub chain: usize,
    /// (iteration, running ℓ₂ marginal error vs uniform) checkpoints.
    pub trajectory: Vec<(u64, f64)>,
    /// Final error.
    pub final_error: f64,
    /// Total factor evaluations (cumulative across resumes).
    pub factor_evals: u64,
    /// Accepted / proposed (1.0 for Gibbs-type samplers).
    pub acceptance: f64,
    /// Steps executed in THIS process (excludes pre-resume iterations).
    pub steps_executed: u64,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Final state.
    pub final_state: Vec<u16>,
    /// Retained trace events (empty unless `trace_capacity > 0`).
    pub trace: Vec<TraceEvent>,
}

/// Aggregated results.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Per-chain reports.
    pub chains: Vec<ChainReport>,
    /// Steps per second aggregated over chains.
    pub steps_per_sec: f64,
    /// Mean factor evaluations per iteration.
    pub evals_per_iter: f64,
    /// End-of-run snapshot of every metric the run touched.
    pub metrics: Snapshot,
}

impl RunReport {
    /// Mean final error across chains.
    pub fn mean_final_error(&self) -> f64 {
        self.chains.iter().map(|c| c.final_error).sum::<f64>() / self.chains.len() as f64
    }
}

/// Run `spec.chains` independent chains in parallel threads.
pub fn run_chains(graph: &FactorGraph, spec: &RunSpec) -> RunReport {
    run_chains_with_metrics(graph, spec, &Arc::new(MetricsHub::new()))
}

/// [`run_chains`] with an externally owned metrics hub: the caller can
/// watch the `sampler_*{chain="k",...}` counter families live from
/// another thread while the run progresses (e.g. the CLI's periodic
/// `--metrics-every` flusher).
pub fn run_chains_with_metrics(
    graph: &FactorGraph,
    spec: &RunSpec,
    hub: &Arc<MetricsHub>,
) -> RunReport {
    let mut master = Pcg64::seeded(spec.seed);
    let streams: Vec<Pcg64> = (0..spec.chains).map(|k| master.split(k as u64)).collect();

    let reports: Vec<ChainReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = streams
            .into_iter()
            .enumerate()
            .map(|(k, rng)| {
                let hub = hub.clone();
                scope.spawn(move || run_one_chain(graph, spec, k, rng, &hub))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let total_secs: f64 = reports.iter().map(|r| r.seconds).sum();
    let executed_steps: u64 = reports.iter().map(|r| r.steps_executed).sum();
    let logical_steps = (spec.iters * spec.chains as u64).max(1);
    let total_evals: u64 = reports.iter().map(|r| r.factor_evals).sum();
    RunReport {
        steps_per_sec: executed_steps as f64 / (total_secs / spec.chains as f64).max(1e-12),
        evals_per_iter: total_evals as f64 / logical_steps as f64,
        chains: reports,
        metrics: hub.snapshot(),
    }
}

/// Record a step-latency sample (and a `Step` trace event) once every
/// this many iterations; amortizes the two `Instant::now()` reads to
/// keep the instrumented step path within the overhead budget.
const LATENCY_SAMPLE: u64 = 16;

fn run_one_chain(
    graph: &FactorGraph,
    spec: &RunSpec,
    k: usize,
    mut rng: Pcg64,
    hub: &MetricsHub,
) -> ChainReport {
    let n = graph.n();
    let d = graph.domain_size() as usize;
    let mut state = spec.init.clone().unwrap_or_else(|| vec![0u16; n]);
    assert_eq!(state.len(), n, "init state has wrong length");
    let mut sampler = spec.sampler.build(graph);

    let chain_label = k.to_string();
    let m = SamplerMetrics::register(
        hub,
        &[("chain", &chain_label), ("sampler", sampler.name())],
    );
    let latency = hub.latency(&labeled("chain_step_latency_ns", &[("chain", &chain_label)]));
    let mut trace_buf = TraceBuffer::new(k as u32, spec.trace_capacity);

    // Resume: adopt the checkpointed position and seed the metric
    // counters with the saved cumulative totals so observability counts
    // the whole logical run, not just this process.
    let mut start_iter = 0u64;
    if spec.resume {
        if let Some(dir) = &spec.checkpoint_dir {
            let path = dir.join(format!("chain{k}.ckpt"));
            if path.exists() {
                let ckpt = Checkpoint::load(&path).expect("resume: unreadable checkpoint");
                assert_eq!(ckpt.seed, spec.seed, "resume: checkpoint seed mismatch");
                assert_eq!(ckpt.chain, k, "resume: checkpoint chain mismatch");
                assert_eq!(ckpt.state.len(), n, "resume: checkpoint state length mismatch");
                assert!(
                    ckpt.iter <= spec.iters,
                    "resume: checkpoint is past the requested iteration count"
                );
                state = ckpt.state;
                start_iter = ckpt.iter;
                m.steps.add(ckpt.iter);
                m.factor_evals.add(ckpt.factor_evals);
                m.accepts.add(ckpt.accepted);
                m.proposals.add(ckpt.proposed);
            }
        }
    }
    sampler.attach_metrics(m.clone());
    sampler.reset(&state, &mut rng);

    let mut sink = MarginalTrajectorySink::new(n, d, spec.record_every);
    let start = Instant::now();
    for it in start_iter..spec.iters {
        if it % LATENCY_SAMPLE == 0 {
            let t0 = Instant::now();
            let st = sampler.step(&mut state, &mut rng);
            latency.record(t0.elapsed());
            crate::trace_event!(trace_buf, EventKind::Step, it, st.factor_evals);
        } else {
            sampler.step(&mut state, &mut rng);
        }
        use super::sink::SampleSink;
        sink.on_sample(it, &state);
        if spec.progress_every > 0 && (it + 1) % spec.progress_every == 0 {
            let done = it + 1 - start_iter;
            let rate = done as f64 / start.elapsed().as_secs_f64().max(1e-9);
            eprintln!(
                "[mbgibbs] chain {k}: iter {}/{} ({rate:.0} steps/s, {} factor evals)",
                it + 1,
                spec.iters,
                m.factor_evals.get(),
            );
            crate::trace_event!(trace_buf, EventKind::Progress, it + 1, 0);
        }
        if spec.checkpoint_every > 0 && (it + 1) % spec.checkpoint_every == 0 {
            if let Some(dir) = &spec.checkpoint_dir {
                let _ = std::fs::create_dir_all(dir);
                let ckpt = Checkpoint {
                    iter: it + 1,
                    seed: spec.seed,
                    chain: k,
                    factor_evals: m.factor_evals.get(),
                    accepted: m.accepts.get(),
                    proposed: m.proposals.get(),
                    state: state.clone(),
                };
                ckpt.save(&dir.join(format!("chain{k}.ckpt")))
                    .expect("checkpoint write failed");
                crate::trace_event!(trace_buf, EventKind::Checkpoint, it + 1, 0);
            }
        }
    }
    {
        use super::sink::SampleSink;
        sink.on_finish(&state);
    }
    let seconds = start.elapsed().as_secs_f64();
    let final_error = sink.estimator().l2_error_vs_uniform();
    ChainReport {
        chain: k,
        trajectory: sink.trajectory,
        final_error,
        factor_evals: m.factor_evals.get(),
        acceptance: m.acceptance(),
        steps_executed: spec.iters - start_iter,
        seconds,
        final_state: state,
        trace: trace_buf.events_in_order(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    #[test]
    fn runs_multiple_chains() {
        let g = models::tiny_random(4, 3, 0.8, 5);
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 20_000;
        spec.chains = 3;
        spec.record_every = 5_000;
        let report = run_chains(&g, &spec);
        assert_eq!(report.chains.len(), 3);
        for c in &report.chains {
            assert!(c.final_error < 0.2, "chain {} error {}", c.chain, c.final_error);
            assert!(!c.trajectory.is_empty());
            assert_eq!(c.acceptance, 1.0);
            assert_eq!(c.steps_executed, 20_000);
        }
        assert!(report.steps_per_sec > 0.0);
        assert!(report.evals_per_iter > 0.0);
    }

    #[test]
    fn chains_use_distinct_streams() {
        let g = models::tiny_random(4, 2, 0.5, 6);
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Generic));
        spec.iters = 500;
        spec.chains = 2;
        let report = run_chains(&g, &spec);
        // Overwhelmingly the final states should differ.
        assert_ne!(
            report.chains[0].final_state, report.chains[1].final_state,
            "chains produced identical trajectories"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let g = models::tiny_random(3, 2, 0.5, 7);
        let mut spec = RunSpec::new(SamplerSpec::Mgpmh { lambda: 3.0 });
        spec.iters = 5_000;
        spec.chains = 2;
        let a = run_chains(&g, &spec);
        let b = run_chains(&g, &spec);
        for (ca, cb) in a.chains.iter().zip(b.chains.iter()) {
            assert_eq!(ca.final_state, cb.final_state);
            assert_eq!(ca.factor_evals, cb.factor_evals);
        }
    }

    #[test]
    fn periodic_checkpoints_written_and_loadable() {
        let g = models::tiny_random(3, 2, 0.5, 9);
        let dir = std::env::temp_dir().join(format!("mbgibbs_run_ckpt_{}", std::process::id()));
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 1_000;
        spec.chains = 2;
        spec.checkpoint_dir = Some(dir.clone());
        spec.checkpoint_every = 400;
        let report = run_chains(&g, &spec);
        for k in 0..2 {
            let ckpt =
                crate::coordinator::Checkpoint::load(&dir.join(format!("chain{k}.ckpt")))
                    .unwrap();
            assert_eq!(ckpt.chain, k);
            assert_eq!(ckpt.iter, 800); // last multiple of 400 within 1000
            assert_eq!(ckpt.state.len(), 3);
            assert!(ckpt.factor_evals > 0, "checkpoint missing cumulative evals");
        }
        assert_eq!(report.chains.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_hub_sees_progress() {
        use std::sync::Arc;
        let g = models::tiny_random(3, 2, 0.5, 10);
        let hub = Arc::new(crate::metrics::MetricsHub::new());
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Generic));
        spec.iters = 10_000;
        spec.chains = 1;
        let report = run_chains_with_metrics(&g, &spec, &hub);
        let snap = hub.snapshot();
        let steps = snap
            .counter("sampler_steps_total{chain=\"0\",sampler=\"gibbs\"}")
            .unwrap();
        assert_eq!(steps, 10_000);
        let evals = snap
            .counter("sampler_factor_evals_total{chain=\"0\",sampler=\"gibbs\"}")
            .unwrap();
        assert!(evals > 0);
        assert_eq!(report.chains[0].factor_evals, evals);
        // Step latency histogram: 1-in-16 sampling over 10k steps.
        let lat = snap.histogram("chain_step_latency_ns{chain=\"0\"}").unwrap();
        assert_eq!(lat.count, 10_000 / LATENCY_SAMPLE);
        assert!(lat.p50 > 0.0);
        // And the run report embeds the same snapshot.
        assert_eq!(report.metrics.counter_family_sum("sampler_steps_total"), 10_000);
    }

    #[test]
    fn respects_custom_init() {
        let g = models::tiny_random(3, 3, 0.3, 8);
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 1;
        spec.init = Some(vec![2, 2, 2]);
        let report = run_chains(&g, &spec);
        // After one step only one variable may have changed.
        let diff = report.chains[0]
            .final_state
            .iter()
            .filter(|&&v| v != 2)
            .count();
        assert!(diff <= 1);
    }

    /// Write checkpoints, then resume on a fresh hub: the resumed run
    /// must pick up at the checkpointed iteration and CONTINUE the
    /// metric counters from the saved totals rather than resetting.
    #[test]
    fn resume_continues_metric_counters() {
        let g = models::tiny_random(3, 2, 0.5, 11);
        let dir = std::env::temp_dir().join(format!("mbgibbs_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 600;
        spec.chains = 1;
        spec.checkpoint_dir = Some(dir.clone());
        spec.checkpoint_every = 300;
        let first = run_chains(&g, &spec);
        let evals_at_600 = first.chains[0].factor_evals;

        // Resume the same run with a higher target: counters continue.
        spec.iters = 1_000;
        spec.resume = true;
        let resumed = run_chains(&g, &spec);
        let c = &resumed.chains[0];
        assert_eq!(c.steps_executed, 400, "should resume at iter 600");
        assert!(
            c.factor_evals > evals_at_600,
            "cumulative evals must grow past the checkpoint total"
        );
        let steps = resumed
            .metrics
            .counter("sampler_steps_total{chain=\"0\",sampler=\"gibbs\"}")
            .unwrap();
        assert_eq!(steps, 1_000, "steps counter must include pre-resume iterations");
        std::fs::remove_dir_all(&dir).ok();
    }
}
