//! The chain runner: fan out chains over threads, aggregate reports.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::bench::workload::SamplerSpec;
use crate::graph::FactorGraph;
use crate::metrics::MetricsHub;
use crate::rng::Pcg64;

use super::checkpoint::Checkpoint;
use super::sink::MarginalTrajectorySink;

/// What to run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Sampler to instantiate per chain.
    pub sampler: SamplerSpec,
    /// Iterations per chain.
    pub iters: u64,
    /// Number of chains (threads).
    pub chains: usize,
    /// Master seed; chain k gets an independent split stream.
    pub seed: u64,
    /// Marginal-error checkpoint cadence.
    pub record_every: u64,
    /// Initial state: `None` = all zeros (the paper's unmixed start).
    pub init: Option<Vec<u16>>,
    /// If set, write a resumable checkpoint per chain every
    /// `checkpoint_every` iterations into this directory.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint cadence (iterations); 0 disables periodic checkpoints.
    pub checkpoint_every: u64,
}

impl RunSpec {
    /// Sensible defaults: 1 chain, 10⁶ iterations, paper's unmixed init.
    pub fn new(sampler: SamplerSpec) -> Self {
        Self {
            sampler,
            iters: 1_000_000,
            chains: 1,
            seed: 42,
            record_every: 10_000,
            init: None,
            checkpoint_dir: None,
            checkpoint_every: 0,
        }
    }
}

/// Per-chain results.
#[derive(Clone, Debug)]
pub struct ChainReport {
    /// Chain index.
    pub chain: usize,
    /// (iteration, running ℓ₂ marginal error vs uniform) checkpoints.
    pub trajectory: Vec<(u64, f64)>,
    /// Final error.
    pub final_error: f64,
    /// Total factor evaluations.
    pub factor_evals: u64,
    /// Accepted / proposed (1.0 for Gibbs-type samplers).
    pub acceptance: f64,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Final state.
    pub final_state: Vec<u16>,
}

/// Aggregated results.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Per-chain reports.
    pub chains: Vec<ChainReport>,
    /// Steps per second aggregated over chains.
    pub steps_per_sec: f64,
    /// Mean factor evaluations per iteration.
    pub evals_per_iter: f64,
}

impl RunReport {
    /// Mean final error across chains.
    pub fn mean_final_error(&self) -> f64 {
        self.chains.iter().map(|c| c.final_error).sum::<f64>() / self.chains.len() as f64
    }
}

/// Run `spec.chains` independent chains in parallel threads.
pub fn run_chains(graph: &FactorGraph, spec: &RunSpec) -> RunReport {
    run_chains_with_metrics(graph, spec, &Arc::new(MetricsHub::new()))
}

/// [`run_chains`] with an externally owned metrics hub: the caller can
/// watch `chain<k>.steps` / `chain<k>.factor_evals` counters live from
/// another thread while the run progresses.
pub fn run_chains_with_metrics(
    graph: &FactorGraph,
    spec: &RunSpec,
    hub: &Arc<MetricsHub>,
) -> RunReport {
    let mut master = Pcg64::seeded(spec.seed);
    let streams: Vec<Pcg64> = (0..spec.chains).map(|k| master.split(k as u64)).collect();

    let reports: Vec<ChainReport> = std::thread::scope(|scope| {
        let handles: Vec<_> = streams
            .into_iter()
            .enumerate()
            .map(|(k, rng)| {
                let hub = hub.clone();
                scope.spawn(move || run_one_chain(graph, spec, k, rng, &hub))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let total_secs: f64 = reports.iter().map(|r| r.seconds).sum();
    let total_steps = spec.iters * spec.chains as u64;
    let total_evals: u64 = reports.iter().map(|r| r.factor_evals).sum();
    RunReport {
        steps_per_sec: total_steps as f64 / (total_secs / spec.chains as f64).max(1e-12),
        evals_per_iter: total_evals as f64 / total_steps as f64,
        chains: reports,
    }
}

fn run_one_chain(
    graph: &FactorGraph,
    spec: &RunSpec,
    k: usize,
    mut rng: Pcg64,
    hub: &MetricsHub,
) -> ChainReport {
    let n = graph.n();
    let d = graph.domain_size() as usize;
    let mut state = spec.init.clone().unwrap_or_else(|| vec![0u16; n]);
    assert_eq!(state.len(), n, "init state has wrong length");
    let mut sampler = spec.sampler.build(graph);
    sampler.reset(&state, &mut rng);
    let mut sink = MarginalTrajectorySink::new(n, d, spec.record_every);
    let steps_counter = hub.counter(&format!("chain{k}.steps"));
    let evals_counter = hub.counter(&format!("chain{k}.factor_evals"));
    // Batch metric updates so the atomics stay off the per-step path.
    const METRICS_BATCH: u64 = 4096;

    let start = Instant::now();
    let mut factor_evals = 0u64;
    let mut accepted = 0u64;
    let mut last_published = 0u64;
    for it in 0..spec.iters {
        let st = sampler.step(&mut state, &mut rng);
        factor_evals += st.factor_evals;
        accepted += st.accepted as u64;
        use super::sink::SampleSink;
        sink.on_sample(it, &state);
        if it % METRICS_BATCH == METRICS_BATCH - 1 {
            steps_counter.add(METRICS_BATCH);
            evals_counter.add(factor_evals - last_published);
            last_published = factor_evals;
        }
        if spec.checkpoint_every > 0 && (it + 1) % spec.checkpoint_every == 0 {
            if let Some(dir) = &spec.checkpoint_dir {
                let _ = std::fs::create_dir_all(dir);
                let ckpt = Checkpoint {
                    iter: it + 1,
                    seed: spec.seed,
                    chain: k,
                    state: state.clone(),
                };
                ckpt.save(&dir.join(format!("chain{k}.ckpt")))
                    .expect("checkpoint write failed");
            }
        }
    }
    steps_counter.add(spec.iters % METRICS_BATCH);
    evals_counter.add(factor_evals - last_published);
    {
        use super::sink::SampleSink;
        sink.on_finish(&state);
    }
    let seconds = start.elapsed().as_secs_f64();
    let final_error = sink.estimator().l2_error_vs_uniform();
    ChainReport {
        chain: k,
        trajectory: sink.trajectory,
        final_error,
        factor_evals,
        acceptance: accepted as f64 / spec.iters.max(1) as f64,
        seconds,
        final_state: state,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    #[test]
    fn runs_multiple_chains() {
        let g = models::tiny_random(4, 3, 0.8, 5);
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 20_000;
        spec.chains = 3;
        spec.record_every = 5_000;
        let report = run_chains(&g, &spec);
        assert_eq!(report.chains.len(), 3);
        for c in &report.chains {
            assert!(c.final_error < 0.2, "chain {} error {}", c.chain, c.final_error);
            assert!(!c.trajectory.is_empty());
            assert_eq!(c.acceptance, 1.0);
        }
        assert!(report.steps_per_sec > 0.0);
        assert!(report.evals_per_iter > 0.0);
    }

    #[test]
    fn chains_use_distinct_streams() {
        let g = models::tiny_random(4, 2, 0.5, 6);
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Generic));
        spec.iters = 500;
        spec.chains = 2;
        let report = run_chains(&g, &spec);
        // Overwhelmingly the final states should differ.
        assert_ne!(
            report.chains[0].final_state, report.chains[1].final_state,
            "chains produced identical trajectories"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let g = models::tiny_random(3, 2, 0.5, 7);
        let mut spec = RunSpec::new(SamplerSpec::Mgpmh { lambda: 3.0 });
        spec.iters = 5_000;
        spec.chains = 2;
        let a = run_chains(&g, &spec);
        let b = run_chains(&g, &spec);
        for (ca, cb) in a.chains.iter().zip(b.chains.iter()) {
            assert_eq!(ca.final_state, cb.final_state);
            assert_eq!(ca.factor_evals, cb.factor_evals);
        }
    }

    #[test]
    fn periodic_checkpoints_written_and_loadable() {
        let g = models::tiny_random(3, 2, 0.5, 9);
        let dir = std::env::temp_dir().join(format!("mbgibbs_run_ckpt_{}", std::process::id()));
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 1_000;
        spec.chains = 2;
        spec.checkpoint_dir = Some(dir.clone());
        spec.checkpoint_every = 400;
        let report = run_chains(&g, &spec);
        for k in 0..2 {
            let ckpt =
                crate::coordinator::Checkpoint::load(&dir.join(format!("chain{k}.ckpt")))
                    .unwrap();
            assert_eq!(ckpt.chain, k);
            assert_eq!(ckpt.iter, 800); // last multiple of 400 within 1000
            assert_eq!(ckpt.state.len(), 3);
        }
        assert_eq!(report.chains.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_hub_sees_progress() {
        use std::sync::Arc;
        let g = models::tiny_random(3, 2, 0.5, 10);
        let hub = Arc::new(crate::metrics::MetricsHub::new());
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Generic));
        spec.iters = 10_000;
        spec.chains = 1;
        run_chains_with_metrics(&g, &spec, &hub);
        let snap: std::collections::BTreeMap<String, u64> =
            hub.snapshot().into_iter().collect();
        assert_eq!(snap["chain0.steps"], 10_000);
        assert!(snap["chain0.factor_evals"] > 0);
    }

    #[test]
    fn respects_custom_init() {
        let g = models::tiny_random(3, 3, 0.3, 8);
        let mut spec = RunSpec::new(SamplerSpec::Gibbs(EnergyPath::Specialized));
        spec.iters = 1;
        spec.init = Some(vec![2, 2, 2]);
        let report = run_chains(&g, &spec);
        // After one step only one variable may have changed.
        let diff = report.chains[0]
            .final_state
            .iter()
            .filter(|&&v| v != 2)
            .count();
        assert!(diff <= 1);
    }
}
