//! Persistent inference service: a daemon that keeps Markov chains warm
//! and answers marginal/conditional queries over TCP.
//!
//! The batch coordinator ([`crate::coordinator`]) runs chains for a
//! fixed iteration budget and exits. This module flips that around for
//! long-lived deployments: a [`ChainPool`] owns N background chains —
//! serial chains replicating `coordinator::runner`'s per-chain
//! discipline bit-for-bit, or chromatic parallel chains driving
//! [`crate::runtime::parallel::ChromaticSweepEngine`] — and each chain
//! periodically folds its samples into a shared [`LiveEstimator`]
//! (running marginals plus windowed cross-chain R̂ / pooled-ESS
//! diagnostics). A [`QueryEngine`] answers point-in-time questions from
//! those live estimates:
//!
//! * `marginal(var)` — pooled running marginal, no extra sampling;
//! * `conditional(var | evidence)` — pins the evidence sites, warm-starts
//!   from the freshest published chain state, and runs a targeted
//!   re-burn-in + estimation sweep on the connection thread; identical
//!   concurrent keys coalesce behind one run and completed results are
//!   served from a TTL'd cache (see [`query`]);
//! * `status` / `metrics` — pool positions, convergence diagnostics, and
//!   the full metrics snapshot.
//!
//! [`Service`] is the front door: a std-only TCP listener speaking
//! newline-delimited JSON, with a minimal HTTP `GET` path so Prometheus
//! can scrape the same port. Shutdown — SIGINT/SIGTERM via [`signal`],
//! or a client `shutdown` request — drains the chains and flushes v2
//! checkpoints, so a restarted service resumes bit-exactly where the
//! previous one stopped.
//!
//! ## Parity contract
//!
//! A pool chain paused at iteration `t` has *exactly* the state, RNG
//! position, and counters the batch runner would have after `t`
//! iterations with the same seed and sampler: RNG streams come from the
//! same master-split order, and `Sampler::step` is the only RNG
//! consumer on the hot loop. Pause watermarks in parallel mode round up
//! to whole chromatic sweeps, mirroring the sweep engine's iteration
//! accounting.
//!
//! With a [`PoolConfig::adapt`] policy, each chain additionally carries
//! the batch runner's adaptive
//! [`Controller`](crate::control::Controller) — λ/λ²/B retune online
//! from live acceptance and evals-per-ESS counters, reviews land at
//! sweep barriers in parallel mode, and tuned values ride the v2
//! checkpoints so adaptive serving resumes bit-exact (see [`pool`]).

pub mod estimator;
pub mod pool;
pub mod query;
pub mod server;
pub mod signal;

pub use estimator::LiveEstimator;
pub use pool::{ChainPool, PoolConfig, RUN_FOREVER};
pub use query::{QueryCacheConfig, QueryDefaults, QueryEngine, Request, MAX_QUERY_STEPS};
pub use server::{Service, ServiceOptions, MAX_REQUEST_BYTES};
