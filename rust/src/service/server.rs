//! The TCP front door: newline-delimited JSON queries plus a minimal
//! HTTP `GET` so Prometheus can scrape the same port.
//!
//! Protocol sniffing happens on the first line of each connection: a
//! line starting with `GET ` is treated as an HTTP/1.x request (headers
//! drained, one `text/plain` response with the Prometheus rendering of
//! the metrics hub, connection closed); anything else enters the NDJSON
//! loop — one request per line, one response line per request, until
//! EOF, a read timeout, or a `shutdown` request.
//!
//! Everything is std-only: a nonblocking accept loop polled against the
//! shutdown flag, one detached handler thread per connection with a
//! read timeout so stale clients can't pin the process.

use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Read, Write as IoWrite};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::graph::FactorGraph;
use crate::metrics::{expose, MetricsHub};

use super::pool::{ChainPool, PoolConfig};
use super::query::{error_response, QueryCacheConfig, QueryDefaults, QueryEngine};
use super::signal;

/// Hard cap on one NDJSON request line (or HTTP header line). A line
/// that exceeds it gets a structured error and the connection closes —
/// an unbounded line would otherwise grow the read buffer without
/// limit.
pub const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Front-door options orthogonal to the pool.
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    /// Bind host.
    pub host: String,
    /// Bind port; 0 = ephemeral (the bound port is in
    /// [`Service::local_addr`]).
    pub port: u16,
    /// Per-connection read timeout; idle clients are dropped after it.
    pub read_timeout: Duration,
    /// Conditional-query defaults.
    pub query: QueryDefaults,
    /// Conditional-result cache + coalescing knobs.
    pub query_cache: QueryCacheConfig,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".to_string(),
            port: 0,
            read_timeout: Duration::from_secs(30),
            query: QueryDefaults::default(),
            query_cache: QueryCacheConfig::default(),
        }
    }
}

/// A running inference service: chain pool + query engine + listener.
pub struct Service {
    addr: SocketAddr,
    accept_handle: JoinHandle<()>,
    pool: ChainPool,
    engine: Arc<QueryEngine>,
    shutdown: Arc<AtomicBool>,
}

impl Service {
    /// Start the pool and the listener. The returned handle owns both;
    /// call [`Service::shutdown`] (or [`Service::run_until_shutdown`])
    /// to stop them and flush checkpoints.
    pub fn start(
        graph: Arc<FactorGraph>,
        pool_cfg: PoolConfig,
        opts: &ServiceOptions,
    ) -> Result<Service> {
        let hub = Arc::new(MetricsHub::new());
        let pool = ChainPool::start(graph.clone(), pool_cfg, hub.clone())?;
        let engine = Arc::new(QueryEngine::new(
            graph,
            pool.live().clone(),
            hub.clone(),
            pool.config().sampler,
            pool.config().seed,
            opts.query,
            opts.query_cache,
        ));

        let listener = TcpListener::bind((opts.host.as_str(), opts.port))
            .with_context(|| format!("binding {}:{}", opts.host, opts.port))?;
        listener
            .set_nonblocking(true)
            .context("setting the listener nonblocking")?;
        let addr = listener.local_addr().context("reading the bound address")?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let accept_handle = {
            let engine = engine.clone();
            let shutdown = shutdown.clone();
            let hub = hub.clone();
            let read_timeout = opts.read_timeout;
            std::thread::Builder::new()
                .name("mbgibbs-accept".to_string())
                .spawn(move || accept_loop(listener, engine, shutdown, hub, read_timeout))
                .context("spawning the accept loop")?
        };
        Ok(Service {
            addr,
            accept_handle,
            pool,
            engine,
            shutdown,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The pool, for watermark control in tests and drain flows.
    pub fn pool(&self) -> &ChainPool {
        &self.pool
    }

    /// The query engine (in-process queries without a socket).
    pub fn engine(&self) -> &Arc<QueryEngine> {
        &self.engine
    }

    /// Has a client sent `{"type":"shutdown"}`?
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    /// Stop accepting, stop the chains (flushing shutdown checkpoints
    /// where configured), and join the accept loop.
    pub fn shutdown(self) -> Result<()> {
        let Service {
            accept_handle,
            pool,
            shutdown,
            ..
        } = self;
        shutdown.store(true, Ordering::Relaxed);
        let _ = accept_handle.join();
        pool.stop()
    }

    /// Serve until SIGINT/SIGTERM or a client `shutdown` request, then
    /// shut down. This is the CLI `serve` loop.
    pub fn run_until_shutdown(self) -> Result<()> {
        signal::install();
        while !self.shutdown_requested() && !signal::triggered() {
            std::thread::sleep(Duration::from_millis(100));
        }
        eprintln!("[mbgibbs] service shutting down");
        self.shutdown()
    }
}

fn accept_loop(
    listener: TcpListener,
    engine: Arc<QueryEngine>,
    shutdown: Arc<AtomicBool>,
    hub: Arc<MetricsHub>,
    read_timeout: Duration,
) {
    let connections = hub.counter("service_connections_total");
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                connections.add(1);
                let engine = engine.clone();
                let shutdown = shutdown.clone();
                let hub = hub.clone();
                let _ = std::thread::Builder::new()
                    .name("mbgibbs-conn".to_string())
                    .spawn(move || {
                        let _ = handle_connection(stream, &engine, &shutdown, &hub, read_timeout);
                    });
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    engine: &QueryEngine,
    shutdown: &AtomicBool,
    hub: &MetricsHub,
    read_timeout: Duration,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(read_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    // Every line read is capped at MAX_REQUEST_BYTES (+1 so the cap
    // itself is representable); an oversized line gets a structured
    // error and the connection closes, since the remainder of the line
    // is still in flight and can't be resynchronized to.
    let cap = MAX_REQUEST_BYTES as u64 + 1;
    loop {
        line.clear();
        let nread = match reader.by_ref().take(cap).read_line(&mut line) {
            Ok(n) => n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                let _ = writer.write_all(error_response("read timeout").as_bytes());
                let _ = writer.write_all(b"\n");
                let _ = writer.flush();
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        if nread == 0 {
            return Ok(()); // EOF: client closed.
        }
        if nread > MAX_REQUEST_BYTES {
            let _ = writer.write_all(
                error_response(&format!("request line exceeds {MAX_REQUEST_BYTES} bytes"))
                    .as_bytes(),
            );
            let _ = writer.write_all(b"\n");
            let _ = writer.flush();
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if trimmed.starts_with("GET ") {
            // Minimal HTTP: drain headers (bounded, same per-line cap),
            // answer with the Prometheus text rendering, close.
            for _ in 0..256 {
                line.clear();
                let n = reader.by_ref().take(cap).read_line(&mut line)?;
                if n == 0 || n > MAX_REQUEST_BYTES || line.trim().is_empty() {
                    break;
                }
            }
            let body = expose::to_prometheus(&hub.snapshot());
            write!(
                writer,
                "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                body.len(),
                body
            )?;
            writer.flush()?;
            return Ok(());
        }
        let (resp, wants_shutdown) = engine.handle_line(trimmed);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if wants_shutdown {
            shutdown.store(true, Ordering::Relaxed);
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::SamplerSpec;
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    fn tiny_service() -> Service {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 41));
        let mut cfg = PoolConfig::new(SamplerSpec::Gibbs(EnergyPath::Specialized), 1);
        cfg.publish_every = 64;
        cfg.pause_at = 256;
        Service::start(g, cfg, &ServiceOptions::default()).unwrap()
    }

    fn roundtrip(addr: SocketAddr, line: &str) -> String {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    }

    #[test]
    fn ndjson_round_trip_over_tcp() {
        let svc = tiny_service();
        svc.pool().wait_until_paused();
        let addr = svc.local_addr();

        let resp = roundtrip(addr, "{\"type\":\"marginal\",\"var\":0}");
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"samples\":256"), "{resp}");

        let resp = roundtrip(addr, "{\"type\":\"status\"}");
        assert!(resp.contains("\"chains\":1"), "{resp}");

        let resp = roundtrip(addr, "not json at all");
        assert!(resp.contains("\"ok\":false"), "{resp}");

        svc.shutdown().unwrap();
    }

    #[test]
    fn prometheus_get_served_on_same_port() {
        let svc = tiny_service();
        svc.pool().wait_until_paused();
        let stream = TcpStream::connect(svc.local_addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n")
            .unwrap();
        let mut response = String::new();
        loop {
            let mut l = String::new();
            if reader.read_line(&mut l).unwrap() == 0 {
                break;
            }
            response.push_str(&l);
        }
        assert!(response.starts_with("HTTP/1.1 200 OK"), "{response}");
        assert!(
            response.contains("sampler_steps_total"),
            "missing sampler counters: {response}"
        );
        svc.shutdown().unwrap();
    }

    #[test]
    fn client_shutdown_request_trips_the_flag() {
        let svc = tiny_service();
        let resp = roundtrip(svc.local_addr(), "{\"type\":\"shutdown\"}");
        assert!(resp.contains("\"ok\":true"), "{resp}");
        // The handler thread sets the flag right after responding.
        for _ in 0..500 {
            if svc.shutdown_requested() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(svc.shutdown_requested());
        svc.shutdown().unwrap();
    }
}
