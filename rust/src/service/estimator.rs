//! Shared live estimates the chain pool publishes into and queries read
//! from.
//!
//! Locking is deliberately light: one mutex per chain slot. A chain
//! thread accumulates samples into a thread-local
//! [`MarginalEstimator`] and only takes its own slot's lock once per
//! publish slice (a few thousand iterations), so chains never contend
//! with each other. Queries lock slots one at a time, each for the
//! duration of a counts merge — microseconds against the pool's
//! steady-state throughput.

use std::sync::Mutex;

use crate::analysis::diagnostics::cross_chain_diagnostics;
use crate::analysis::MarginalEstimator;

/// One chain's published position.
struct Slot {
    marginals: MarginalEstimator,
    /// Thinned total-energy series ζ(x) — the scalar the cross-chain
    /// R̂ / pooled-ESS diagnostics run on. Bounded to the newest
    /// `window` points.
    energy: Vec<f64>,
    /// Iteration of the most recent publish.
    iter: u64,
    /// State at the most recent publish; empty before the first one.
    state: Vec<u16>,
}

/// Per-chain slots of running marginals, energy traces, and last-seen
/// states, merged on demand into pooled answers.
pub struct LiveEstimator {
    slots: Vec<Mutex<Slot>>,
    n: usize,
    d: usize,
    window: usize,
}

impl LiveEstimator {
    /// For `chains` chains over `n` variables with domain size `d`,
    /// keeping at most `window` energy points per chain.
    pub fn new(n: usize, d: usize, chains: usize, window: usize) -> Self {
        assert!(chains > 0, "need at least one chain slot");
        assert!(window >= 2, "diagnostics need an energy window of >= 2");
        let slots = (0..chains)
            .map(|_| {
                Mutex::new(Slot {
                    marginals: MarginalEstimator::new(n, d),
                    energy: Vec::new(),
                    iter: 0,
                    state: Vec::new(),
                })
            })
            .collect();
        Self { slots, n, d, window }
    }

    /// Number of variables n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Domain size D.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of chain slots.
    pub fn chains(&self) -> usize {
        self.slots.len()
    }

    /// Fold a chain's local slice into its slot: merge marginal counts,
    /// append energies (keeping the newest `window`), and record the
    /// chain's position. Called by chain threads only, each with its own
    /// `chain` index.
    pub fn publish(
        &self,
        chain: usize,
        local: &MarginalEstimator,
        energies: &[f64],
        iter: u64,
        state: &[u16],
    ) {
        let mut slot = self.slots[chain].lock().unwrap();
        slot.marginals.merge(local);
        slot.energy.extend_from_slice(energies);
        if slot.energy.len() > self.window {
            let drop = slot.energy.len() - self.window;
            slot.energy.drain(..drop);
        }
        slot.iter = iter;
        slot.state.clear();
        slot.state.extend_from_slice(state);
    }

    /// Cross-chain pooled estimator (counts summed over every chain).
    pub fn pooled(&self) -> MarginalEstimator {
        let mut acc = MarginalEstimator::new(self.n, self.d);
        for s in &self.slots {
            acc.merge(&s.lock().unwrap().marginals);
        }
        acc
    }

    /// Pooled marginal of variable `i` plus the sample count behind it.
    /// `None` if `i` is out of range.
    pub fn marginal(&self, i: usize) -> Option<(Vec<f64>, u64)> {
        if i >= self.n {
            return None;
        }
        let pooled = self.pooled();
        Some((pooled.marginal(i), pooled.samples()))
    }

    /// Total samples across chains.
    pub fn total_samples(&self) -> u64 {
        self.slots
            .iter()
            .map(|s| s.lock().unwrap().marginals.samples())
            .sum()
    }

    /// Each chain's last published iteration.
    pub fn chain_iters(&self) -> Vec<u64> {
        self.slots.iter().map(|s| s.lock().unwrap().iter).collect()
    }

    /// Cross-chain `(R̂, pooled ESS)` over the windowed energy traces.
    pub fn diagnostics(&self) -> (Option<f64>, Option<f64>) {
        let traces: Vec<Vec<f64>> = self
            .slots
            .iter()
            .map(|s| s.lock().unwrap().energy.clone())
            .collect();
        let views: Vec<&[f64]> = traces.iter().map(|t| t.as_slice()).collect();
        cross_chain_diagnostics(&views)
    }

    /// The most advanced chain's `(state, iter)` — the warmest start for
    /// a conditional query's re-burn-in. `None` before any publish.
    pub fn freshest_state(&self) -> Option<(Vec<u16>, u64)> {
        let mut best: Option<(Vec<u16>, u64)> = None;
        for s in &self.slots {
            let slot = s.lock().unwrap();
            if slot.state.is_empty() {
                continue;
            }
            let newer = match &best {
                None => true,
                Some((_, it)) => slot.iter > *it,
            };
            if newer {
                best = Some((slot.state.clone(), slot.iter));
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_merges_and_pools() {
        let live = LiveEstimator::new(2, 2, 2, 16);
        let mut a = MarginalEstimator::new(2, 2);
        a.update(&[0, 1]);
        a.update(&[0, 1]);
        live.publish(0, &a, &[1.0, 2.0], 2, &[0, 1]);
        let mut b = MarginalEstimator::new(2, 2);
        b.update(&[1, 1]);
        live.publish(1, &b, &[3.0], 1, &[1, 1]);

        assert_eq!(live.total_samples(), 3);
        let (dist, samples) = live.marginal(0).unwrap();
        assert_eq!(samples, 3);
        assert!((dist[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!(live.marginal(7).is_none());
        assert_eq!(live.chain_iters(), vec![2, 1]);
        let (state, iter) = live.freshest_state().unwrap();
        assert_eq!((state, iter), (vec![0, 1], 2));
    }

    #[test]
    fn energy_window_is_bounded() {
        let live = LiveEstimator::new(1, 2, 1, 4);
        let empty = MarginalEstimator::new(1, 2);
        live.publish(0, &empty, &[1.0, 2.0, 3.0], 3, &[0]);
        live.publish(0, &empty, &[4.0, 5.0, 6.0], 6, &[0]);
        // Window of 4 keeps the newest 4 points; a single chain yields
        // ESS but no R̂.
        let (rhat, ess) = live.diagnostics();
        assert!(rhat.is_none());
        assert!(ess.unwrap() <= 4.0 + 1e-9);
    }

    /// Degenerate energy windows — zero variance across chains, or a
    /// NaN point from an overflowed ζ(x) — must never surface NaN to
    /// the status/metrics JSON; `None` (→ `null`) is the contract.
    #[test]
    fn diagnostics_clamp_degenerate_windows() {
        let live = LiveEstimator::new(1, 2, 2, 16);
        let empty = MarginalEstimator::new(1, 2);
        live.publish(0, &empty, &[2.0, 2.0, 2.0], 3, &[0]);
        live.publish(1, &empty, &[2.0, 2.0, 2.0], 3, &[1]);
        let (rhat, ess) = live.diagnostics();
        assert_eq!(rhat, Some(1.0), "zero-variance window pins R̂ at 1");
        assert!(ess.unwrap().is_finite());

        let poisoned = LiveEstimator::new(1, 2, 2, 16);
        poisoned.publish(0, &empty, &[1.0, f64::NAN, 2.0], 3, &[0]);
        poisoned.publish(1, &empty, &[1.0, 1.5, 2.0], 3, &[1]);
        assert_eq!(
            poisoned.diagnostics(),
            (None, None),
            "NaN energy must clamp both diagnostics to null"
        );
    }

    #[test]
    fn diagnostics_need_two_points() {
        let live = LiveEstimator::new(1, 2, 2, 16);
        assert_eq!(live.diagnostics(), (None, None));
        let empty = MarginalEstimator::new(1, 2);
        live.publish(0, &empty, &[1.0, 2.0, 1.5], 3, &[0]);
        live.publish(1, &empty, &[1.1, 2.2, 1.4], 3, &[1]);
        let (rhat, ess) = live.diagnostics();
        assert!(rhat.is_some());
        assert!(ess.unwrap() > 0.0);
    }
}
