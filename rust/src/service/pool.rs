//! The chain pool: N background chains feeding the shared
//! [`LiveEstimator`].
//!
//! Each chain replays the coordinator's per-chain discipline exactly —
//! the same master-seed split order, the same `attach_metrics` →
//! `reset` → `restore_aux_energy` sequence, and a step loop whose only
//! RNG consumer is `sampler.step` — so a pool chain paused at iteration
//! N is bit-identical to a batch [`run_chains`](crate::coordinator)
//! chain run for N iterations with the same seed. That equivalence is
//! what lets the service answer queries that match batch estimates and
//! resume batch-written v2 checkpoints (and vice versa).
//!
//! Control plane: a shared `pause_at` watermark (`u64::MAX` = run
//! forever) and a `stop` flag. Chains poll both; at the watermark they
//! flush their pending slice into the estimator and idle, which gives
//! tests and drain-style shutdowns a deterministic iteration count.
//!
//! With `workers >= 1` a chain runs chromatic systematic sweeps on the
//! [`ChromaticSweepEngine`]; slice and pause boundaries are rounded up
//! to whole sweeps (n site updates) because intermediate states only
//! materialize at sweep boundaries.
//!
//! With a non-[`Off`](crate::control::ControlPolicy::Off) `adapt`
//! policy, each chain carries its own [`Controller`] and retunes λ/λ²/B
//! online from its live acceptance-rate and evals-per-ESS counters.
//! Serial chains review every `adapt_every` iterations like the batch
//! runner. Parallel chains review at the first *sweep barrier* on or
//! after each `adapt_every` boundary: workers apply hyperparameters at
//! slice start, so adjustments only take effect between engine slices,
//! and keying reviews to absolute iteration boundaries (not slice
//! counts) keeps the adaptation schedule invariant under worker count
//! and publish cadence. Tuned values ride in the v2 checkpoint flush;
//! a resume whose checkpoint landed on a review boundary (pause at a
//! multiple of `adapt_every`, sweep-aligned in parallel mode) replays
//! bit-exactly under the target-accept policy.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::MarginalEstimator;
use crate::bench::workload::SamplerSpec;
use crate::control::{ControlPolicy, Controller};
use crate::coordinator::Checkpoint;
use crate::graph::FactorGraph;
use crate::metrics::{MetricsHub, SamplerMetrics};
use crate::rng::Pcg64;
use crate::runtime::parallel::ChromaticSweepEngine;
use crate::samplers::Sampler;

use super::estimator::LiveEstimator;

/// `pause_at` value meaning "never pause".
pub const RUN_FOREVER: u64 = u64::MAX;

/// How a pool runs its chains. Mirrors the coordinator's
/// [`RunSpec`](crate::coordinator::RunSpec) minus the fixed iteration
/// count — a pool runs until told otherwise.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Sampler to instantiate per chain.
    pub sampler: SamplerSpec,
    /// Number of background chains.
    pub chains: usize,
    /// Master seed; chain k gets the same split stream as batch chain k.
    pub seed: u64,
    /// Within-chain parallel workers; 0 = serial random scan.
    pub workers: usize,
    /// Energy-trace thinning: record ζ(x) every this many iterations.
    pub record_every: u64,
    /// Iterations accumulated locally before merging into the shared
    /// estimator (the lock cadence).
    pub publish_every: u64,
    /// Iterations before samples start counting toward the marginals
    /// (the energy trace is gated the same way). Does not perturb the
    /// RNG stream, so bit-exactness with batch runs holds for any value.
    pub burn_in: u64,
    /// Newest energy points kept per chain for R̂ / ESS.
    pub window: usize,
    /// Where checkpoints live (same `chain<k>.ckpt` files and v2 format
    /// as the batch runner).
    pub checkpoint_dir: Option<PathBuf>,
    /// Flush a checkpoint per chain when the pool stops.
    pub checkpoint_on_shutdown: bool,
    /// Resume from `checkpoint_dir/chain<k>.ckpt` where present.
    pub resume: bool,
    /// Initial pause watermark; [`RUN_FOREVER`] starts free-running,
    /// a finite value starts the pool in a drained-at-N state (tests,
    /// fixed-budget warm-up).
    pub pause_at: u64,
    /// Adaptive-control policy: [`ControlPolicy::Off`] (default) runs
    /// fixed hyperparameters; anything else gives each chain its own
    /// [`Controller`] (parallel chains review at sweep barriers).
    pub adapt: ControlPolicy,
}

impl PoolConfig {
    /// A pool of `chains` serial chains of `sampler`, free-running.
    pub fn new(sampler: SamplerSpec, chains: usize) -> Self {
        Self {
            sampler,
            chains,
            seed: 42,
            workers: 0,
            record_every: 1_000,
            publish_every: 4_096,
            burn_in: 0,
            window: 4_096,
            checkpoint_dir: None,
            checkpoint_on_shutdown: false,
            resume: false,
            pause_at: RUN_FOREVER,
            adapt: ControlPolicy::Off,
        }
    }
}

/// Shared control plane between the pool handle and its chain threads.
struct Control {
    stop: AtomicBool,
    pause_at: AtomicU64,
}

/// Owns the chain threads and the estimator they feed.
pub struct ChainPool {
    handles: Vec<JoinHandle<Result<()>>>,
    live: Arc<LiveEstimator>,
    control: Arc<Control>,
    cfg: PoolConfig,
    /// Sweep length for watermark alignment in parallel mode.
    n: u64,
}

impl ChainPool {
    /// Validate the config and launch the chain threads.
    pub fn start(
        graph: Arc<FactorGraph>,
        cfg: PoolConfig,
        hub: Arc<MetricsHub>,
    ) -> Result<ChainPool> {
        if cfg.chains == 0 {
            bail!("pool needs at least one chain");
        }
        if cfg.record_every == 0 || cfg.publish_every == 0 {
            bail!("record_every and publish_every must be > 0");
        }
        if cfg.workers > 0 && !cfg.sampler.supports_parallel() {
            bail!(
                "workers > 0 needs a site-local sampler (Gibbs, Local, MGPMH); \
                 {:?} carries global augmented-space state",
                cfg.sampler
            );
        }
        if cfg.resume && cfg.checkpoint_dir.is_none() {
            bail!("resume requires a checkpoint_dir");
        }
        if cfg.checkpoint_on_shutdown && cfg.checkpoint_dir.is_none() {
            bail!("checkpoint_on_shutdown requires a checkpoint_dir");
        }
        cfg.adapt.validate()?;

        let n = graph.n() as u64;
        let live = Arc::new(LiveEstimator::new(
            graph.n(),
            graph.domain_size() as usize,
            cfg.chains,
            cfg.window.max(2),
        ));
        let control = Arc::new(Control {
            stop: AtomicBool::new(false),
            pause_at: AtomicU64::new(cfg.pause_at),
        });

        // Same stream derivation as run_chains: split the master in
        // chain order, so pool chain k == batch chain k.
        let mut master = Pcg64::seeded(cfg.seed);
        let mut handles = Vec::with_capacity(cfg.chains);
        for k in 0..cfg.chains {
            let rng = master.split(k as u64);
            let graph = graph.clone();
            let cfg = cfg.clone();
            let live = live.clone();
            let control = control.clone();
            let hub = hub.clone();
            let handle = std::thread::Builder::new()
                .name(format!("mbgibbs-chain-{k}"))
                .spawn(move || chain_main(&graph, &cfg, k, rng, &live, &control, &hub))
                .context("spawning pool chain thread")?;
            handles.push(handle);
        }
        Ok(ChainPool {
            handles,
            live,
            control,
            cfg,
            n,
        })
    }

    /// The shared estimator queries read from.
    pub fn live(&self) -> &Arc<LiveEstimator> {
        &self.live
    }

    /// The pool's configuration.
    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Move the pause watermark: chains run up to iteration `iter`
    /// (rounded up to a whole sweep in parallel mode), flush, and idle.
    /// [`RUN_FOREVER`] resumes free-running.
    pub fn pause_at(&self, iter: u64) {
        self.control.pause_at.store(iter, Ordering::Relaxed);
    }

    /// The watermark every chain must reach for
    /// [`ChainPool::wait_until_paused`], accounting for sweep rounding.
    fn aligned_watermark(&self) -> u64 {
        let pause = self.control.pause_at.load(Ordering::Relaxed);
        if pause == RUN_FOREVER || self.cfg.workers == 0 {
            return pause;
        }
        pause.div_ceil(self.n) * self.n
    }

    /// Block until every chain has published at or past the current
    /// watermark (no-op when free-running). After this returns, the
    /// estimator reflects every iteration up to the watermark.
    pub fn wait_until_paused(&self) {
        let target = self.aligned_watermark();
        if target == RUN_FOREVER {
            return;
        }
        loop {
            if self.live.chain_iters().iter().all(|&it| it >= target) {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Stop the chains, flush shutdown checkpoints (if configured), and
    /// join the threads. Returns the first chain error, if any.
    pub fn stop(self) -> Result<()> {
        self.control.stop.store(true, Ordering::Relaxed);
        let mut first_err = None;
        for h in self.handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => first_err = first_err.or_else(|| Some(anyhow!("chain thread panicked"))),
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

fn chain_main(
    graph: &FactorGraph,
    cfg: &PoolConfig,
    k: usize,
    rng: Pcg64,
    live: &LiveEstimator,
    control: &Control,
    hub: &MetricsHub,
) -> Result<()> {
    if cfg.workers > 0 {
        chain_main_parallel(graph, cfg, k, rng, live, control, hub)
    } else {
        chain_main_serial(graph, cfg, k, rng, live, control, hub)
    }
}

/// Load `chain<k>.ckpt` if resuming and present, seeding the metric
/// counters with the saved totals. Returns
/// `(start_iter, rng_parts, site_rng_parts, aux_energy, hyperparams_applied)`
/// with the state written in place.
#[allow(clippy::type_complexity)]
fn maybe_resume(
    cfg: &PoolConfig,
    k: usize,
    n: usize,
    state: &mut Vec<u16>,
    sampler: &mut dyn Sampler,
    m: &SamplerMetrics,
) -> Result<(u64, Option<(u128, u128)>, Option<Vec<(u128, u128)>>, Option<f64>)> {
    if !cfg.resume {
        return Ok((0, None, None, None));
    }
    let dir = cfg.checkpoint_dir.as_ref().expect("validated in start()");
    let path = dir.join(format!("chain{k}.ckpt"));
    if !path.exists() {
        return Ok((0, None, None, None));
    }
    let ckpt = Checkpoint::load(&path)?;
    if ckpt.seed != cfg.seed {
        bail!("resume: checkpoint seed {} != pool seed {}", ckpt.seed, cfg.seed);
    }
    if ckpt.chain != k {
        bail!("resume: checkpoint chain {} != {}", ckpt.chain, k);
    }
    if ckpt.state.len() != n {
        bail!(
            "resume: checkpoint has {} variables, graph has {n}",
            ckpt.state.len()
        );
    }
    *state = ckpt.state;
    m.steps.add(ckpt.iter);
    m.factor_evals.add(ckpt.factor_evals);
    m.accepts.add(ckpt.accepted);
    m.proposals.add(ckpt.proposed);
    if !ckpt.hyperparams.is_empty() {
        sampler.set_hyperparams(&ckpt.hyperparams);
    }
    Ok((ckpt.iter, ckpt.rng, ckpt.site_rngs, ckpt.aux_energy))
}

/// Write a v2 checkpoint in the batch runner's format/location.
#[allow(clippy::too_many_arguments)]
fn flush_checkpoint(
    dir: &Path,
    cfg: &PoolConfig,
    k: usize,
    iter: u64,
    state: &[u16],
    m: &SamplerMetrics,
    rng: &Pcg64,
    site_rngs: Option<Vec<(u128, u128)>>,
    sampler: &dyn Sampler,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let ckpt = Checkpoint {
        iter,
        seed: cfg.seed,
        chain: k,
        factor_evals: m.factor_evals.get(),
        accepted: m.accepts.get(),
        proposed: m.proposals.get(),
        rng: Some(rng.state_parts()),
        hyperparams: sampler.hyperparams(),
        aux_energy: sampler.aux_energy(),
        site_rngs,
        state: state.to_vec(),
    };
    ckpt.save(&dir.join(format!("chain{k}.ckpt")))
}

fn chain_main_serial(
    graph: &FactorGraph,
    cfg: &PoolConfig,
    k: usize,
    mut rng: Pcg64,
    live: &LiveEstimator,
    control: &Control,
    hub: &MetricsHub,
) -> Result<()> {
    let n = graph.n();
    let d = graph.domain_size() as usize;
    let mut state = vec![0u16; n];
    let mut sampler = cfg.sampler.build(graph);

    let chain_label = k.to_string();
    let m = SamplerMetrics::register(hub, &[("chain", &chain_label), ("sampler", sampler.name())]);

    let (start_iter, rng_parts, _, restored_aux) =
        maybe_resume(cfg, k, n, &mut state, sampler.as_mut(), &m)?;
    if let Some((s, inc)) = rng_parts {
        rng = Pcg64::from_state_parts(s, inc);
    }
    // Same order as the batch runner: attach, reset, then restore the
    // augmented-space cache the reset just recomputed from scratch.
    sampler.attach_metrics(m.clone());
    sampler.reset(&state, &mut rng);
    if let Some(e) = restored_aux {
        sampler.restore_aux_energy(e);
    }

    // Adaptive control, wired exactly like the batch runner: the
    // controller snapshots the (possibly resume-seeded) counters at
    // construction so its first window covers only iterations it saw.
    let mut controller = Controller::new(&cfg.adapt, hub, &chain_label, m.clone(), graph.stats());
    if let Some(c) = &controller {
        c.publish(sampler.as_ref());
    }
    // Cumulative marginal-error trajectory for plateau detection — the
    // same (iteration, ℓ₂-error-vs-uniform) checkpoints as the batch
    // runner's trajectory sink, recorded every `record_every`. Only
    // maintained when a controller is active; it never touches the RNG.
    let mut traj_est = controller.as_ref().map(|_| MarginalEstimator::new(n, d));
    let mut trajectory: Vec<(u64, f64)> = Vec::new();

    let mut it = start_iter;
    let mut local = MarginalEstimator::new(n, d);
    let mut local_energy: Vec<f64> = Vec::new();
    // Sentinel forces a flush at the first pause even if nothing ran.
    let mut published_at = u64::MAX;
    loop {
        if control.stop.load(Ordering::Relaxed) {
            break;
        }
        if it >= control.pause_at.load(Ordering::Relaxed) {
            if published_at != it {
                live.publish(k, &local, &local_energy, it, &state);
                local.reset();
                local_energy.clear();
                published_at = it;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        sampler.step(&mut state, &mut rng);
        if let Some(est) = traj_est.as_mut() {
            est.update(&state);
            if it % cfg.record_every == 0 {
                trajectory.push((it, est.l2_error_vs_uniform()));
            }
        }
        if it >= cfg.burn_in {
            local.update(&state);
            if it % cfg.record_every == 0 {
                local_energy.push(graph.total_energy(&state));
            }
        }
        it += 1;
        if let Some(c) = controller.as_mut() {
            if c.due(it) {
                let action = c.review(it, sampler.as_mut(), &trajectory);
                if action.save_checkpoint {
                    if let Some(dir) = &cfg.checkpoint_dir {
                        flush_checkpoint(
                            dir,
                            cfg,
                            k,
                            it,
                            &state,
                            &m,
                            &rng,
                            None,
                            sampler.as_ref(),
                        )?;
                    }
                }
            }
        }
        if it % cfg.publish_every == 0 {
            live.publish(k, &local, &local_energy, it, &state);
            local.reset();
            local_energy.clear();
            published_at = it;
        }
    }
    live.publish(k, &local, &local_energy, it, &state);
    if cfg.checkpoint_on_shutdown {
        if let Some(dir) = &cfg.checkpoint_dir {
            flush_checkpoint(dir, cfg, k, it, &state, &m, &rng, None, sampler.as_ref())?;
        }
    }
    Ok(())
}

fn chain_main_parallel(
    graph: &FactorGraph,
    cfg: &PoolConfig,
    k: usize,
    mut rng: Pcg64,
    live: &LiveEstimator,
    control: &Control,
    hub: &MetricsHub,
) -> Result<()> {
    let n = graph.n();
    let nn = n as u64;
    let mut state = vec![0u16; n];
    // The probe never steps: it carries the name and the
    // (possibly checkpoint-restored) hyperparameters, like the batch
    // parallel path. Sampling instances live in the engine's workers.
    let mut probe = cfg.sampler.build(graph);

    let chain_label = k.to_string();
    let m = SamplerMetrics::register(hub, &[("chain", &chain_label), ("sampler", probe.name())]);

    let (start_iter, _, saved_site_rngs, _) =
        maybe_resume(cfg, k, n, &mut state, probe.as_mut(), &m)?;

    let mut engine = {
        let mut e = ChromaticSweepEngine::new(
            graph,
            cfg.sampler,
            cfg.workers,
            &mut rng,
            m.clone(),
            hub,
            &chain_label,
        );
        e.set_hyperparams(probe.hyperparams());
        if let Some(parts) = &saved_site_rngs {
            e.restore_site_rngs(parts)
                .context("resume: checkpoint site streams do not match this graph")?;
        }
        e
    };

    // Sweep-barrier adaptation: workers copy hyperparameters at slice
    // start, so a review can only take effect between engine slices.
    // Slices are therefore capped at the next `adapt_every` boundary
    // (rounded up to a whole sweep), which keys the review schedule to
    // absolute iteration counts — invariant under worker count and
    // publish cadence. Counter sums are deterministic at slice ends
    // (workers join), so review inputs are worker-count invariant too.
    let mut controller = Controller::new(&cfg.adapt, hub, &chain_label, m.clone(), graph.stats());
    if let Some(c) = &controller {
        c.publish(probe.as_ref());
    }
    let every = cfg.adapt.adapt_every().max(1);
    let mut traj_est =
        controller.as_ref().map(|_| MarginalEstimator::new(n, graph.domain_size() as usize));
    let mut trajectory: Vec<(u64, f64)> = Vec::new();

    // Advance in whole sweeps so states materialize at the same
    // boundaries as the batch parallel path.
    let slice = cfg.publish_every.div_ceil(nn).max(1) * nn;
    let mut it = start_iter;
    let mut local = MarginalEstimator::new(n, graph.domain_size() as usize);
    let mut local_energy: Vec<f64> = Vec::new();
    let mut published_at = u64::MAX;
    loop {
        if control.stop.load(Ordering::Relaxed) {
            break;
        }
        let pause = control.pause_at.load(Ordering::Relaxed);
        let pause_aligned = if pause == RUN_FOREVER {
            RUN_FOREVER
        } else {
            pause.div_ceil(nn).saturating_mul(nn)
        };
        if it >= pause_aligned {
            if published_at != it {
                live.publish(k, &local, &local_energy, it, &state);
                local.reset();
                local_energy.clear();
                published_at = it;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        let mut end = pause_aligned.min(it.saturating_add(slice));
        if controller.is_some() {
            let next_review = ((it / every) + 1).saturating_mul(every);
            let review_aligned = next_review.div_ceil(nn).saturating_mul(nn);
            end = end.min(review_aligned);
        }
        engine.run(&mut state, it, end, &mut |ctx| {
            if let Some(est) = traj_est.as_mut() {
                est.update(ctx.state);
                if ctx.iter % cfg.record_every == 0 {
                    trajectory.push((ctx.iter, est.l2_error_vs_uniform()));
                }
            }
            if ctx.iter > cfg.burn_in {
                local.update(ctx.state);
                if ctx.iter % cfg.record_every == 0 {
                    local_energy.push(graph.total_energy(ctx.state));
                }
            }
        });
        let prev = it;
        it = end;
        if let Some(c) = controller.as_mut() {
            if c.due_crossing(prev, it) {
                let action = c.review(it, probe.as_mut(), &trajectory);
                engine.set_hyperparams(probe.hyperparams());
                if action.save_checkpoint {
                    if let Some(dir) = &cfg.checkpoint_dir {
                        let site_rngs = Some(engine.site_rng_parts());
                        flush_checkpoint(
                            dir,
                            cfg,
                            k,
                            it,
                            &state,
                            &m,
                            &rng,
                            site_rngs,
                            probe.as_ref(),
                        )?;
                    }
                }
            }
        }
        live.publish(k, &local, &local_energy, it, &state);
        local.reset();
        local_energy.clear();
        published_at = it;
    }
    live.publish(k, &local, &local_energy, it, &state);
    if cfg.checkpoint_on_shutdown {
        if let Some(dir) = &cfg.checkpoint_dir {
            let site_rngs = Some(engine.site_rng_parts());
            flush_checkpoint(dir, cfg, k, it, &state, &m, &rng, site_rngs, probe.as_ref())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Rng;
    use crate::samplers::EnergyPath;

    fn gibbs() -> SamplerSpec {
        SamplerSpec::Gibbs(EnergyPath::Specialized)
    }

    /// A pool chain paused at iteration N must be bit-identical to a
    /// hand-rolled replica of the batch per-chain loop run N steps.
    #[test]
    fn pool_matches_batch_discipline_bit_exactly() {
        let g = Arc::new(models::tiny_random(4, 3, 0.8, 21));
        let (chains, iters, seed) = (2usize, 6_000u64, 99u64);

        let mut cfg = PoolConfig::new(gibbs(), chains);
        cfg.seed = seed;
        cfg.record_every = 500;
        cfg.publish_every = 512;
        cfg.pause_at = iters;
        let pool = ChainPool::start(g.clone(), cfg, Arc::new(MetricsHub::new())).unwrap();
        pool.wait_until_paused();

        // Replica of run_chains' per-chain loop.
        let mut reference = MarginalEstimator::new(g.n(), g.domain_size() as usize);
        let mut master = Pcg64::seeded(seed);
        for k in 0..chains {
            let mut rng = master.split(k as u64);
            let mut state = vec![0u16; g.n()];
            let mut sampler = gibbs().build(&g);
            sampler.reset(&state, &mut rng);
            for _ in 0..iters {
                sampler.step(&mut state, &mut rng);
                reference.update(&state);
            }
        }

        let pooled = pool.live().pooled();
        assert_eq!(pooled.samples(), reference.samples());
        for i in 0..g.n() {
            assert_eq!(
                pooled.marginal(i),
                reference.marginal(i),
                "pooled marginal {i} diverged from the batch replica"
            );
        }
        pool.stop().unwrap();
    }

    #[test]
    fn watermark_can_be_raised() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 22));
        let mut cfg = PoolConfig::new(gibbs(), 1);
        cfg.publish_every = 64;
        cfg.pause_at = 128;
        let pool = ChainPool::start(g, cfg, Arc::new(MetricsHub::new())).unwrap();
        pool.wait_until_paused();
        assert_eq!(pool.live().chain_iters(), vec![128]);
        assert_eq!(pool.live().total_samples(), 128);
        pool.pause_at(256);
        pool.wait_until_paused();
        assert_eq!(pool.live().total_samples(), 256);
        pool.stop().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 23));
        let hub = Arc::new(MetricsHub::new());
        let mut cfg = PoolConfig::new(gibbs(), 0);
        assert!(ChainPool::start(g.clone(), cfg.clone(), hub.clone()).is_err());
        cfg.chains = 1;
        cfg.resume = true;
        assert!(
            ChainPool::start(g.clone(), cfg.clone(), hub.clone()).is_err(),
            "resume without a checkpoint dir"
        );
        cfg.resume = false;
        cfg.sampler = SamplerSpec::MinGibbs { lambda: 10.0 };
        cfg.workers = 2;
        assert!(
            ChainPool::start(g.clone(), cfg.clone(), hub.clone()).is_err(),
            "MIN-Gibbs carries global state; parallel must be rejected"
        );
        cfg.sampler = gibbs();
        cfg.workers = 0;
        cfg.adapt = ControlPolicy::target_acceptance(1.5);
        assert!(
            ChainPool::start(g, cfg, hub).is_err(),
            "out-of-range adapt target must be rejected at start()"
        );
    }

    /// An adaptive serial chain with a wildly oversized λ must steer it
    /// down, and the shutdown checkpoint must carry the tuned value.
    #[test]
    fn adaptive_serial_chain_tunes_lambda_into_checkpoint() {
        let g = Arc::new(models::tiny_random(4, 3, 0.8, 26));
        let dir = std::env::temp_dir().join(format!("mbgibbs_pool_adapt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let lambda0 = 400.0;
        let mut cfg = PoolConfig::new(SamplerSpec::Mgpmh { lambda: lambda0 }, 1);
        cfg.seed = 13;
        cfg.publish_every = 256;
        // Keep the trajectory short so the plateau detector never
        // freezes the controller inside this window.
        cfg.record_every = 1_000_000;
        cfg.adapt = ControlPolicy::target_acceptance(0.7).with_adapt_every(500);
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_on_shutdown = true;
        cfg.pause_at = 2_000;
        let hub = Arc::new(MetricsHub::new());
        let pool = ChainPool::start(g, cfg, hub.clone()).unwrap();
        pool.wait_until_paused();
        pool.stop().unwrap();

        let ckpt = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
        let tuned = ckpt
            .hyperparams
            .lambda
            .expect("MGPMH checkpoint carries lambda");
        assert!(
            tuned < lambda0,
            "target-accept should shrink an oversized λ, got {tuned}"
        );
        let snap = hub.snapshot();
        assert_eq!(
            snap.gauge("controller_lambda{chain=\"0\"}"),
            Some(tuned),
            "live gauge must track the tuned value"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Shutdown at a watermark, resume, run to 2N: the final checkpoint
    /// must equal an uninterrupted pool run to 2N — and both must equal
    /// the batch runner's chain — state AND rng position.
    #[test]
    fn shutdown_resume_is_bit_exact() {
        let g = Arc::new(models::tiny_random(4, 3, 0.8, 24));
        let dir = std::env::temp_dir().join(format!("mbgibbs_pool_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let seed = 7u64;

        let mk = |resume: bool, pause: u64| {
            let mut cfg = PoolConfig::new(SamplerSpec::MinGibbs { lambda: 40.0 }, 1);
            cfg.seed = seed;
            cfg.publish_every = 256;
            cfg.checkpoint_dir = Some(dir.clone());
            cfg.checkpoint_on_shutdown = true;
            cfg.resume = resume;
            cfg.pause_at = pause;
            cfg
        };

        // Leg 1: run to 1000, stop (flushes chain0.ckpt at 1000).
        let pool = ChainPool::start(g.clone(), mk(false, 1_000), Arc::new(MetricsHub::new()))
            .unwrap();
        pool.wait_until_paused();
        pool.stop().unwrap();
        let mid = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
        assert_eq!(mid.iter, 1_000);
        assert!(mid.rng.is_some());

        // Leg 2: resume to 2000.
        let pool = ChainPool::start(g.clone(), mk(true, 2_000), Arc::new(MetricsHub::new()))
            .unwrap();
        pool.wait_until_paused();
        pool.stop().unwrap();
        let resumed = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
        assert_eq!(resumed.iter, 2_000);

        // Uninterrupted pool to 2000 in a fresh dir.
        let dir2 = std::env::temp_dir()
            .join(format!("mbgibbs_pool_resume2_{}", std::process::id()));
        std::fs::remove_dir_all(&dir2).ok();
        let mut cfg = mk(false, 2_000);
        cfg.checkpoint_dir = Some(dir2.clone());
        let pool = ChainPool::start(g, cfg, Arc::new(MetricsHub::new())).unwrap();
        pool.wait_until_paused();
        pool.stop().unwrap();
        let full = Checkpoint::load(&dir2.join("chain0.ckpt")).unwrap();

        assert_eq!(resumed.state, full.state, "resume diverged from uninterrupted");
        assert_eq!(resumed.rng, full.rng, "rng position diverged");
        assert_eq!(resumed.factor_evals, full.factor_evals);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    /// Parallel pool chains pause at sweep-aligned watermarks and their
    /// state matches a batch parallel run of the same length.
    #[test]
    fn parallel_pool_matches_batch_engine() {
        let g = Arc::new(models::ising_multipartite(3, 6, 1.5));
        let n = g.n() as u64;
        let iters = n * 40;

        let mut cfg = PoolConfig::new(gibbs(), 1);
        cfg.seed = 5;
        cfg.workers = 2;
        cfg.record_every = n * 5;
        cfg.publish_every = n * 10;
        cfg.pause_at = iters;
        let dir = std::env::temp_dir().join(format!("mbgibbs_pool_par_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_on_shutdown = true;
        let pool = ChainPool::start(g.clone(), cfg, Arc::new(MetricsHub::new())).unwrap();
        pool.wait_until_paused();
        pool.stop().unwrap();
        let ckpt = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
        assert_eq!(ckpt.iter, iters);
        assert!(ckpt.site_rngs.is_some(), "parallel checkpoint needs site streams");

        let spec = crate::coordinator::RunSpec::builder(gibbs())
            .iters(iters)
            .seed(5)
            .record_every(n * 5)
            .workers(2)
            .build()
            .unwrap();
        let report =
            crate::coordinator::run_chains(&g, &spec, &crate::coordinator::RunOptions::default());
        assert_eq!(
            ckpt.state, report.chains[0].final_state,
            "parallel pool diverged from the batch engine"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Burn-in gates what the estimator sees without perturbing the
    /// chain: totals only count post-burn-in samples.
    #[test]
    fn burn_in_gates_samples() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 25));
        let mut cfg = PoolConfig::new(gibbs(), 1);
        cfg.burn_in = 100;
        cfg.publish_every = 64;
        cfg.pause_at = 300;
        let pool = ChainPool::start(g, cfg, Arc::new(MetricsHub::new())).unwrap();
        pool.wait_until_paused();
        assert_eq!(pool.live().total_samples(), 200);
        pool.stop().unwrap();
    }

    /// Master-split streams are deterministic — the parity tests above
    /// rely on replaying the exact split order.
    #[test]
    fn split_streams_are_deterministic() {
        let mut a = Pcg64::seeded(3);
        let mut b = Pcg64::seeded(3);
        assert_eq!(a.split(0).next_u64(), b.split(0).next_u64());
    }
}
