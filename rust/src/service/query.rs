//! The query engine: turns NDJSON request lines into NDJSON response
//! lines against the live pool state.
//!
//! Requests (one JSON object per line):
//!
//! ```text
//! {"type":"marginal","var":3}
//! {"type":"conditional","var":3,"evidence":{"0":1,"17":0},"burn_in":2000,"samples":4000}
//! {"type":"status"}
//! {"type":"metrics"}
//! {"type":"shutdown"}
//! ```
//!
//! `marginal` reads the pooled running estimate — O(D) after a
//! per-chain counts merge, no sampling. `conditional` clones the most
//! advanced chain's state, pins the evidence sites, and runs a targeted
//! re-burn-in plus sample pass over the *free* sites only, on the query
//! thread — the pool's chains never stall for a query. Evidence pinning
//! restricts the random scan to free sites, which leaves the conditional
//! distribution π(x_free | x_evidence) invariant for every sampler in
//! the crate (Gibbs resamples exact conditionals; the minibatch MH
//! kernels are π-reversible per site).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::bench::workload::SamplerSpec;
use crate::config::json::JsonValue;
use crate::graph::FactorGraph;
use crate::metrics::expose::esc;
use crate::metrics::{labeled, MetricsHub};
use crate::rng::{Pcg64, Rng};
use crate::samplers::{Sampler, StepStats};

use super::estimator::LiveEstimator;

/// A parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Pooled running marginal of one variable.
    Marginal {
        /// Variable index.
        var: usize,
    },
    /// Conditional marginal given pinned evidence.
    Conditional {
        /// Variable index to estimate.
        var: usize,
        /// `(site, value)` pins, deduplicated, sorted by site.
        evidence: Vec<(usize, u16)>,
        /// Re-burn-in steps (default: the engine's configured value).
        burn_in: Option<u64>,
        /// Recorded sample steps (default: the engine's configured value).
        samples: Option<u64>,
    },
    /// Pool status: per-chain iterations, sample totals, R̂/ESS.
    Status,
    /// Full metrics snapshot as embedded JSON.
    Metrics,
    /// Ask the service to shut down (checkpoints flush on the way out).
    Shutdown,
}

/// Parse one NDJSON request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let doc = JsonValue::parse(line).map_err(|e| anyhow!("invalid JSON: {e}"))?;
    let ty = doc
        .get("type")
        .and_then(|v| v.as_str())
        .context("request needs a string \"type\" field")?;
    let get_index = |key: &str| -> Result<usize> {
        let v = doc
            .get(key)
            .and_then(|v| v.as_f64())
            .with_context(|| format!("{ty:?} request needs a numeric {key:?} field"))?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("{key} must be a non-negative integer, got {v}");
        }
        Ok(v as usize)
    };
    let get_opt_u64 = |key: &str| -> Result<Option<u64>> {
        match doc.get(key) {
            None => Ok(None),
            Some(v) => {
                let f = v
                    .as_f64()
                    .with_context(|| format!("{key} must be a number"))?;
                if f < 0.0 || f.fract() != 0.0 {
                    bail!("{key} must be a non-negative integer, got {f}");
                }
                Ok(Some(f as u64))
            }
        }
    };
    match ty {
        "marginal" => Ok(Request::Marginal {
            var: get_index("var")?,
        }),
        "conditional" => {
            let var = get_index("var")?;
            let obj = doc
                .get("evidence")
                .and_then(|v| v.as_object())
                .context("conditional request needs an \"evidence\" object {\"site\": value}")?;
            let mut evidence = Vec::with_capacity(obj.len());
            for (key, val) in obj {
                let site: usize = key
                    .parse()
                    .with_context(|| format!("evidence key {key:?} is not a variable index"))?;
                let v = val
                    .as_f64()
                    .with_context(|| format!("evidence value for site {site} must be a number"))?;
                if v < 0.0 || v.fract() != 0.0 {
                    bail!("evidence value for site {site} must be a non-negative integer");
                }
                evidence.push((site, v as u16));
            }
            // BTreeMap keys iterate in string order; re-sort numerically.
            evidence.sort_unstable();
            Ok(Request::Conditional {
                var,
                evidence,
                burn_in: get_opt_u64("burn_in")?,
                samples: get_opt_u64("samples")?,
            })
        }
        "status" => Ok(Request::Status),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        other => bail!("unknown request type {other:?}"),
    }
}

/// Wraps any crate sampler so the random scan only visits free
/// (non-evidence) sites; pinned sites are never selected, so their
/// values persist and the chain targets π(x_free | x_evidence).
struct EvidenceSampler<'g> {
    inner: Box<dyn Sampler + 'g>,
    free: Vec<usize>,
}

impl Sampler for EvidenceSampler<'_> {
    fn update_site(&mut self, site: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        self.inner.update_site(site, state, rng)
    }

    fn select_site(&mut self, _state: &[u16], rng: &mut dyn Rng) -> usize {
        self.free[rng.index(self.free.len())]
    }

    fn name(&self) -> &'static str {
        "evidence"
    }

    fn reset(&mut self, state: &[u16], rng: &mut dyn Rng) {
        self.inner.reset(state, rng);
    }
}

/// Conditional-query defaults (per-request overrides win).
#[derive(Clone, Copy, Debug)]
pub struct QueryDefaults {
    /// Re-burn-in steps over the free sites after pinning evidence.
    pub burn_in: u64,
    /// Recorded sample steps.
    pub samples: u64,
}

impl Default for QueryDefaults {
    fn default() -> Self {
        Self {
            burn_in: 2_000,
            samples: 4_000,
        }
    }
}

/// Answers queries against the live estimator and graph.
pub struct QueryEngine {
    graph: Arc<FactorGraph>,
    live: Arc<LiveEstimator>,
    hub: Arc<MetricsHub>,
    sampler: SamplerSpec,
    seed: u64,
    defaults: QueryDefaults,
    seq: AtomicU64,
}

/// Render a one-line error response.
pub fn error_response(msg: &str) -> String {
    format!("{{\"ok\":false,\"error\":\"{}\"}}", esc(msg))
}

fn json_dist(dist: &[f64]) -> String {
    let toks: Vec<String> = dist
        .iter()
        .map(|v| {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        })
        .collect();
    format!("[{}]", toks.join(","))
}

fn json_opt(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x}"),
        _ => "null".to_string(),
    }
}

impl QueryEngine {
    /// Wire up against a pool's estimator. `sampler`/`seed` must match
    /// the pool so conditional chains run the same kernel family.
    pub fn new(
        graph: Arc<FactorGraph>,
        live: Arc<LiveEstimator>,
        hub: Arc<MetricsHub>,
        sampler: SamplerSpec,
        seed: u64,
        defaults: QueryDefaults,
    ) -> Self {
        Self {
            graph,
            live,
            hub,
            sampler,
            seed,
            defaults,
            seq: AtomicU64::new(0),
        }
    }

    /// Handle one raw request line. Returns the one-line response and
    /// whether the request asked for shutdown.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        let t0 = Instant::now();
        let (resp, ty, shutdown) = match parse_request(line) {
            Err(e) => (error_response(&format!("{e:#}")), "invalid", false),
            Ok(req) => {
                let ty = match &req {
                    Request::Marginal { .. } => "marginal",
                    Request::Conditional { .. } => "conditional",
                    Request::Status => "status",
                    Request::Metrics => "metrics",
                    Request::Shutdown => "shutdown",
                };
                let shutdown = req == Request::Shutdown;
                let resp = match self.handle(&req) {
                    Ok(r) => r,
                    Err(e) => error_response(&format!("{e:#}")),
                };
                (resp, ty, shutdown)
            }
        };
        self.hub
            .counter(&labeled("service_queries_total", &[("type", ty)]))
            .add(1);
        self.hub
            .latency(&labeled("service_query_latency_ns", &[("type", ty)]))
            .record(t0.elapsed());
        (resp, shutdown)
    }

    /// Handle a parsed request.
    pub fn handle(&self, req: &Request) -> Result<String> {
        match req {
            Request::Marginal { var } => self.marginal(*var),
            Request::Conditional {
                var,
                evidence,
                burn_in,
                samples,
            } => self.conditional(*var, evidence, *burn_in, *samples),
            Request::Status => Ok(self.status()),
            Request::Metrics => Ok(self.metrics()),
            Request::Shutdown => Ok("{\"ok\":true,\"type\":\"shutdown\"}".to_string()),
        }
    }

    fn marginal(&self, var: usize) -> Result<String> {
        let (dist, samples) = self
            .live
            .marginal(var)
            .with_context(|| format!("var {var} out of range (n = {})", self.graph.n()))?;
        Ok(format!(
            "{{\"ok\":true,\"type\":\"marginal\",\"var\":{var},\"dist\":{},\"samples\":{samples}}}",
            json_dist(&dist)
        ))
    }

    fn conditional(
        &self,
        var: usize,
        evidence: &[(usize, u16)],
        burn_in: Option<u64>,
        samples: Option<u64>,
    ) -> Result<String> {
        let n = self.graph.n();
        let d = self.graph.domain_size() as usize;
        if var >= n {
            bail!("var {var} out of range (n = {n})");
        }
        let mut pinned = vec![false; n];
        for &(site, val) in evidence {
            if site >= n {
                bail!("evidence site {site} out of range (n = {n})");
            }
            if (val as usize) >= d {
                bail!("evidence value {val} for site {site} out of range (D = {d})");
            }
            if pinned[site] {
                bail!("evidence pins site {site} twice");
            }
            pinned[site] = true;
        }

        // Pinning the query variable makes the answer a point mass.
        if pinned[var] {
            let val = evidence.iter().find(|(s, _)| *s == var).unwrap().1;
            let mut dist = vec![0.0; d];
            dist[val as usize] = 1.0;
            return Ok(format!(
                "{{\"ok\":true,\"type\":\"conditional\",\"var\":{var},\"dist\":{},\
                 \"samples\":0,\"burn_in\":0,\"pinned\":true}}",
                json_dist(&dist)
            ));
        }
        let free: Vec<usize> = (0..n).filter(|&i| !pinned[i]).collect();

        // Warm start from the most advanced chain (all zeros before any
        // publish), then pin the evidence.
        let mut state = match self.live.freshest_state() {
            Some((s, _)) => s,
            None => vec![0u16; n],
        };
        for &(site, val) in evidence {
            state[site] = val;
        }

        let burn = burn_in.unwrap_or(self.defaults.burn_in);
        let keep = samples.unwrap_or(self.defaults.samples).max(1);
        // Deterministic per-process: each query gets its own stream off
        // the pool seed.
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut rng = Pcg64::with_stream(self.seed, 0x5EED_C0DE ^ seq);
        let mut sampler = EvidenceSampler {
            inner: self.sampler.build(&self.graph),
            free,
        };
        sampler.reset(&state, &mut rng);
        for _ in 0..burn {
            sampler.step(&mut state, &mut rng);
        }
        let mut counts = vec![0u64; d];
        for _ in 0..keep {
            sampler.step(&mut state, &mut rng);
            counts[state[var] as usize] += 1;
        }
        let dist: Vec<f64> = counts.iter().map(|&c| c as f64 / keep as f64).collect();
        Ok(format!(
            "{{\"ok\":true,\"type\":\"conditional\",\"var\":{var},\"dist\":{},\
             \"samples\":{keep},\"burn_in\":{burn}}}",
            json_dist(&dist)
        ))
    }

    fn status(&self) -> String {
        let iters = self.live.chain_iters();
        let (rhat, ess) = self.live.diagnostics();
        let iter_toks: Vec<String> = iters.iter().map(|i| i.to_string()).collect();
        format!(
            "{{\"ok\":true,\"type\":\"status\",\"chains\":{},\"iters\":[{}],\
             \"samples\":{},\"rhat\":{},\"pooled_ess\":{},\
             \"model\":{{\"n\":{},\"d\":{},\"factors\":{}}},\"sampler\":\"{}\"}}",
            self.live.chains(),
            iter_toks.join(","),
            self.live.total_samples(),
            json_opt(rhat),
            json_opt(ess),
            self.graph.n(),
            self.graph.domain_size(),
            self.graph.num_factors(),
            esc(&self.sampler.label(&self.graph)),
        )
    }

    fn metrics(&self) -> String {
        // The exposition JSON is multi-line; raw newlines only occur as
        // token separators (strings escape theirs), so flattening them
        // to spaces keeps the document valid and the response one line.
        let snap = crate::metrics::expose::to_json(&self.hub.snapshot());
        let flat = snap.replace('\n', " ");
        format!(
            "{{\"ok\":true,\"type\":\"metrics\",\"snapshot\":{}}}",
            flat.trim()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{exact_distribution, StateSpace};
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    fn engine_over(g: Arc<FactorGraph>, chains: usize) -> (QueryEngine, Arc<LiveEstimator>) {
        let live = Arc::new(LiveEstimator::new(g.n(), g.domain_size() as usize, chains, 64));
        let engine = QueryEngine::new(
            g,
            live.clone(),
            Arc::new(MetricsHub::new()),
            SamplerSpec::Gibbs(EnergyPath::Specialized),
            11,
            QueryDefaults::default(),
        );
        (engine, live)
    }

    #[test]
    fn parses_requests() {
        assert_eq!(
            parse_request("{\"type\":\"marginal\",\"var\":3}").unwrap(),
            Request::Marginal { var: 3 }
        );
        let line = "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":1,\"2\":0}}";
        let req = parse_request(line).unwrap();
        assert_eq!(
            req,
            Request::Conditional {
                var: 1,
                evidence: vec![(0, 1), (2, 0)],
                burn_in: None,
                samples: None,
            }
        );
        assert_eq!(parse_request("{\"type\":\"status\"}").unwrap(), Request::Status);
        assert_eq!(parse_request("{\"type\":\"shutdown\"}").unwrap(), Request::Shutdown);
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{\"type\":\"nope\"}").is_err());
        assert!(parse_request("{\"type\":\"marginal\",\"var\":-1}").is_err());
        assert!(parse_request("{\"type\":\"marginal\",\"var\":1.5}").is_err());
        assert!(parse_request("{\"type\":\"conditional\",\"var\":0}").is_err());
    }

    #[test]
    fn marginal_reads_live_counts() {
        let g = Arc::new(models::tiny_random(2, 2, 0.5, 31));
        let (engine, live) = engine_over(g, 1);
        let mut local = crate::analysis::MarginalEstimator::new(2, 2);
        local.update(&[0, 1]);
        local.update(&[1, 1]);
        live.publish(0, &local, &[], 2, &[1, 1]);
        let resp = engine.handle(&Request::Marginal { var: 0 }).unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"samples\":2"), "{resp}");
        assert!(resp.contains("\"dist\":[0.5,0.5]"), "{resp}");
        assert!(engine.handle(&Request::Marginal { var: 9 }).is_err());
    }

    /// The conditional sampler must converge to the exact enumerated
    /// conditional π(x_var | evidence) on a tiny model.
    #[test]
    fn conditional_matches_enumeration() {
        let g = Arc::new(models::tiny_random(4, 3, 0.9, 32));
        let (engine, _) = engine_over(g.clone(), 1);
        let evidence = vec![(0usize, 2u16), (3usize, 1u16)];
        let var = 1usize;

        // Exact conditional by enumeration.
        let space = StateSpace::for_graph(&g);
        let pi = exact_distribution(&g);
        let d = g.domain_size() as usize;
        let mut num = vec![0.0f64; d];
        let mut den = 0.0f64;
        for idx in 0..space.len() {
            let s = space.state(idx);
            if evidence.iter().all(|&(site, val)| s[site] == val) {
                num[s[var] as usize] += pi[idx];
                den += pi[idx];
            }
        }
        let exact: Vec<f64> = num.iter().map(|&x| x / den).collect();

        let resp = engine
            .handle(&Request::Conditional {
                var,
                evidence,
                burn_in: Some(2_000),
                samples: Some(60_000),
            })
            .unwrap();
        // Pull the dist array back out of the response line.
        let doc = JsonValue::parse(&resp).unwrap();
        let dist: Vec<f64> = doc
            .get("dist")
            .and_then(|v| v.as_array())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (u, (&got, &want)) in dist.iter().zip(exact.iter()).enumerate() {
            assert!(
                (got - want).abs() < 0.02,
                "conditional[{u}] = {got}, exact = {want}"
            );
        }
    }

    #[test]
    fn conditional_on_pinned_var_is_point_mass() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 33));
        let (engine, _) = engine_over(g, 1);
        let resp = engine
            .handle(&Request::Conditional {
                var: 0,
                evidence: vec![(0, 1)],
                burn_in: None,
                samples: None,
            })
            .unwrap();
        assert!(resp.contains("\"dist\":[0,1]"), "{resp}");
        assert!(resp.contains("\"pinned\":true"), "{resp}");
    }

    #[test]
    fn conditional_validates_evidence() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 34));
        let (engine, _) = engine_over(g, 1);
        let bad_site = Request::Conditional {
            var: 0,
            evidence: vec![(9, 0)],
            burn_in: None,
            samples: None,
        };
        assert!(engine.handle(&bad_site).is_err());
        let bad_val = Request::Conditional {
            var: 0,
            evidence: vec![(1, 7)],
            burn_in: None,
            samples: None,
        };
        assert!(engine.handle(&bad_val).is_err());
    }

    #[test]
    fn status_and_metrics_render_valid_json() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 35));
        let (engine, live) = engine_over(g, 2);
        let empty = crate::analysis::MarginalEstimator::new(3, 2);
        live.publish(0, &empty, &[1.0, 2.0], 10, &[0, 0, 0]);
        let (resp, shutdown) = engine.handle_line("{\"type\":\"status\"}");
        assert!(!shutdown);
        let doc = JsonValue::parse(&resp).unwrap();
        assert_eq!(doc.get("chains").and_then(|v| v.as_f64()), Some(2.0));
        assert!(!resp.contains('\n'));

        let (resp, _) = engine.handle_line("{\"type\":\"metrics\"}");
        let doc = JsonValue::parse(&resp).unwrap();
        assert!(doc.get("snapshot").is_some(), "{resp}");
        assert!(!resp.contains('\n'));

        let (resp, shutdown) = engine.handle_line("{\"type\":\"shutdown\"}");
        assert!(shutdown);
        assert!(resp.contains("\"ok\":true"));

        let (resp, shutdown) = engine.handle_line("garbage");
        assert!(!shutdown);
        assert!(resp.contains("\"ok\":false"));
    }
}
