//! The query engine: turns NDJSON request lines into NDJSON response
//! lines against the live pool state.
//!
//! Requests (one JSON object per line):
//!
//! ```text
//! {"type":"marginal","var":3}
//! {"type":"conditional","var":3,"evidence":{"0":1,"17":0},"burn_in":2000,"samples":4000}
//! {"type":"status"}
//! {"type":"metrics"}
//! {"type":"shutdown"}
//! ```
//!
//! `marginal` reads the pooled running estimate — O(D) after a
//! per-chain counts merge, no sampling. `conditional` clones the most
//! advanced chain's state, pins the evidence sites, and runs a targeted
//! re-burn-in plus sample pass over the *free* sites only, on the query
//! thread — the pool's chains never stall for a query. Evidence pinning
//! restricts the random scan to free sites, which leaves the conditional
//! distribution π(x_free | x_evidence) invariant for every sampler in
//! the crate (Gibbs resamples exact conditionals; the minibatch MH
//! kernels are π-reversible per site).
//!
//! Conditional work is batched two ways. Concurrent requests pinning
//! the same `(evidence, burn_in, samples)` key are *coalesced*: the
//! first to arrive runs the re-burn-in, the rest block on a keyed
//! in-flight cell and share its result. Completed results then live in
//! a TTL'd evidence-keyed cache so bursts spread over a few seconds hit
//! memory, not the sampler. A run records full marginals over every
//! variable, so any `var` with the same key is served by the same
//! chain. The per-key RNG stream is derived from the key itself (not a
//! request sequence number), which makes coalesced, cached, and
//! uncached answers for one key bit-identical. `no_cache` (or a
//! disabled cache) bypasses both layers.
//!
//! Request handling is panic-proof: `handle_line` catches panics from
//! the handler, returns a structured `{"error": ...}` line, and bumps
//! `service_request_panics_total` — one bad request can't take down a
//! connection thread silently.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::MarginalEstimator;
use crate::bench::workload::SamplerSpec;
use crate::config::json::JsonValue;
use crate::graph::FactorGraph;
use crate::metrics::expose::esc;
use crate::metrics::{labeled, Counter, MetricsHub};
use crate::rng::{Pcg64, Rng};
use crate::samplers::{Sampler, StepStats};

use super::estimator::LiveEstimator;

/// Hard ceiling on `burn_in + samples` for one conditional request, so
/// a single NDJSON line can't pin a connection thread for hours.
pub const MAX_QUERY_STEPS: u64 = 50_000_000;

/// A parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Pooled running marginal of one variable.
    Marginal {
        /// Variable index.
        var: usize,
    },
    /// Conditional marginal given pinned evidence.
    Conditional {
        /// Variable index to estimate.
        var: usize,
        /// `(site, value)` pins, deduplicated, sorted by site.
        evidence: Vec<(usize, u16)>,
        /// Re-burn-in steps (default: the engine's configured value).
        burn_in: Option<u64>,
        /// Recorded sample steps (default: the engine's configured value).
        samples: Option<u64>,
        /// Bypass the result cache and in-flight coalescing: always run
        /// a fresh conditional chain.
        no_cache: bool,
    },
    /// Pool status: per-chain iterations, sample totals, R̂/ESS.
    Status,
    /// Full metrics snapshot as embedded JSON.
    Metrics,
    /// Ask the service to shut down (checkpoints flush on the way out).
    Shutdown,
}

/// Parse one NDJSON request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let doc = JsonValue::parse(line).map_err(|e| anyhow!("invalid JSON: {e}"))?;
    let ty = doc
        .get("type")
        .and_then(|v| v.as_str())
        .context("request needs a string \"type\" field")?;
    let get_index = |key: &str| -> Result<usize> {
        let v = doc
            .get(key)
            .and_then(|v| v.as_f64())
            .with_context(|| format!("{ty:?} request needs a numeric {key:?} field"))?;
        if v < 0.0 || v.fract() != 0.0 {
            bail!("{key} must be a non-negative integer, got {v}");
        }
        Ok(v as usize)
    };
    let get_opt_u64 = |key: &str| -> Result<Option<u64>> {
        match doc.get(key) {
            None => Ok(None),
            Some(v) => {
                let f = v
                    .as_f64()
                    .with_context(|| format!("{key} must be a number"))?;
                if f < 0.0 || f.fract() != 0.0 {
                    bail!("{key} must be a non-negative integer, got {f}");
                }
                Ok(Some(f as u64))
            }
        }
    };
    match ty {
        "marginal" => Ok(Request::Marginal {
            var: get_index("var")?,
        }),
        "conditional" => {
            let var = get_index("var")?;
            let obj = doc
                .get("evidence")
                .and_then(|v| v.as_object())
                .context("conditional request needs an \"evidence\" object {\"site\": value}")?;
            let mut evidence = Vec::with_capacity(obj.len());
            for (key, val) in obj {
                let site: usize = key
                    .parse()
                    .with_context(|| format!("evidence key {key:?} is not a variable index"))?;
                let v = val
                    .as_f64()
                    .with_context(|| format!("evidence value for site {site} must be a number"))?;
                if v < 0.0 || v.fract() != 0.0 {
                    bail!("evidence value for site {site} must be a non-negative integer");
                }
                evidence.push((site, v as u16));
            }
            // BTreeMap keys iterate in string order; re-sort numerically.
            evidence.sort_unstable();
            let no_cache = match doc.get("no_cache") {
                None => false,
                Some(v) => v
                    .as_bool()
                    .context("no_cache must be a boolean")?,
            };
            Ok(Request::Conditional {
                var,
                evidence,
                burn_in: get_opt_u64("burn_in")?,
                samples: get_opt_u64("samples")?,
                no_cache,
            })
        }
        "status" => Ok(Request::Status),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        other => bail!("unknown request type {other:?}"),
    }
}

/// Wraps any crate sampler so the random scan only visits free
/// (non-evidence) sites; pinned sites are never selected, so their
/// values persist and the chain targets π(x_free | x_evidence).
struct EvidenceSampler<'g> {
    inner: Box<dyn Sampler + 'g>,
    free: Vec<usize>,
}

impl Sampler for EvidenceSampler<'_> {
    fn update_site(&mut self, site: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        self.inner.update_site(site, state, rng)
    }

    fn select_site(&mut self, _state: &[u16], rng: &mut dyn Rng) -> usize {
        self.free[rng.index(self.free.len())]
    }

    fn name(&self) -> &'static str {
        "evidence"
    }

    fn reset(&mut self, state: &[u16], rng: &mut dyn Rng) {
        self.inner.reset(state, rng);
    }
}

/// Conditional-query defaults (per-request overrides win).
#[derive(Clone, Copy, Debug)]
pub struct QueryDefaults {
    /// Re-burn-in steps over the free sites after pinning evidence.
    pub burn_in: u64,
    /// Recorded sample steps.
    pub samples: u64,
}

impl Default for QueryDefaults {
    fn default() -> Self {
        Self {
            burn_in: 2_000,
            samples: 4_000,
        }
    }
}

/// Conditional result cache + coalescing knobs (`[service.query_cache]`
/// in config).
#[derive(Clone, Copy, Debug)]
pub struct QueryCacheConfig {
    /// Master switch; off disables the TTL cache *and* in-flight
    /// coalescing (every request runs its own chain).
    pub enabled: bool,
    /// How long a completed result stays servable.
    pub ttl: Duration,
    /// Max cached evidence keys; the oldest entry is evicted first.
    pub capacity: usize,
}

impl Default for QueryCacheConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            ttl: Duration::from_millis(2_000),
            capacity: 64,
        }
    }
}

/// What one conditional chain run is keyed by: the sorted evidence pins
/// plus the burn/sample budget. `var` is deliberately absent — a run
/// records marginals for every variable, so one key serves them all.
type CondKey = (Vec<(usize, u16)>, u64, u64);

/// Full per-variable marginals from one conditional chain run.
#[derive(Clone)]
struct CondResult {
    dists: Vec<Vec<f64>>,
}

/// Coalescing + cache state, all under one lock so a cache fill and the
/// matching in-flight removal are atomic (stragglers either join the
/// pending cell or hit the cache — never recompute).
struct CondState {
    inflight: HashMap<CondKey, Arc<OnceLock<CondResult>>>,
    cache: HashMap<CondKey, (Instant, CondResult)>,
}

/// Answers queries against the live estimator and graph.
pub struct QueryEngine {
    graph: Arc<FactorGraph>,
    live: Arc<LiveEstimator>,
    hub: Arc<MetricsHub>,
    sampler: SamplerSpec,
    seed: u64,
    defaults: QueryDefaults,
    cache_cfg: QueryCacheConfig,
    cond: Mutex<CondState>,
    coalesced_total: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    runs_total: Arc<Counter>,
    panics_total: Arc<Counter>,
}

/// Render a one-line error response.
pub fn error_response(msg: &str) -> String {
    format!("{{\"ok\":false,\"error\":\"{}\"}}", esc(msg))
}

/// FNV-1a over the conditional key: a stable 64-bit stream selector so
/// a key's RNG stream is a pure function of (evidence, burn, samples).
fn stream_key(key: &CondKey) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mix = |h: &mut u64, x: u64| {
        for b in x.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(PRIME);
        }
    };
    for &(site, val) in &key.0 {
        mix(&mut h, site as u64);
        mix(&mut h, val as u64);
    }
    mix(&mut h, key.1);
    mix(&mut h, key.2);
    h
}

fn json_dist(dist: &[f64]) -> String {
    let toks: Vec<String> = dist
        .iter()
        .map(|v| {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        })
        .collect();
    format!("[{}]", toks.join(","))
}

fn json_opt(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x}"),
        _ => "null".to_string(),
    }
}

impl QueryEngine {
    /// Wire up against a pool's estimator. `sampler`/`seed` must match
    /// the pool so conditional chains run the same kernel family.
    pub fn new(
        graph: Arc<FactorGraph>,
        live: Arc<LiveEstimator>,
        hub: Arc<MetricsHub>,
        sampler: SamplerSpec,
        seed: u64,
        defaults: QueryDefaults,
        cache_cfg: QueryCacheConfig,
    ) -> Self {
        let coalesced_total = hub.counter("service_conditional_coalesced_total");
        let cache_hits = hub.counter("service_conditional_cache_hits_total");
        let cache_misses = hub.counter("service_conditional_cache_misses_total");
        let runs_total = hub.counter("service_conditional_runs_total");
        let panics_total = hub.counter("service_request_panics_total");
        Self {
            graph,
            live,
            hub,
            sampler,
            seed,
            defaults,
            cache_cfg,
            cond: Mutex::new(CondState {
                inflight: HashMap::new(),
                cache: HashMap::new(),
            }),
            coalesced_total,
            cache_hits,
            cache_misses,
            runs_total,
            panics_total,
        }
    }

    /// Lock the coalescing state, recovering from poisoning: the maps
    /// stay structurally valid across a panicking holder, and a caught
    /// panic must not brick every later conditional.
    fn lock_cond(&self) -> MutexGuard<'_, CondState> {
        self.cond.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Handle one raw request line. Returns the one-line response and
    /// whether the request asked for shutdown. A panicking handler is
    /// caught and surfaced as a structured error line — the connection
    /// (and listener) keep serving.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        let t0 = Instant::now();
        let (resp, ty, shutdown) = match parse_request(line) {
            Err(e) => (error_response(&format!("{e:#}")), "invalid", false),
            Ok(req) => {
                let ty = match &req {
                    Request::Marginal { .. } => "marginal",
                    Request::Conditional { .. } => "conditional",
                    Request::Status => "status",
                    Request::Metrics => "metrics",
                    Request::Shutdown => "shutdown",
                };
                let shutdown = req == Request::Shutdown;
                let resp = match catch_unwind(AssertUnwindSafe(|| self.handle(&req))) {
                    Ok(Ok(r)) => r,
                    Ok(Err(e)) => error_response(&format!("{e:#}")),
                    Err(_) => {
                        self.panics_total.add(1);
                        error_response("internal error: request handler panicked")
                    }
                };
                (resp, ty, shutdown)
            }
        };
        self.hub
            .counter(&labeled("service_queries_total", &[("type", ty)]))
            .add(1);
        self.hub
            .latency(&labeled("service_query_latency_ns", &[("type", ty)]))
            .record(t0.elapsed());
        (resp, shutdown)
    }

    /// Handle a parsed request.
    pub fn handle(&self, req: &Request) -> Result<String> {
        match req {
            Request::Marginal { var } => self.marginal(*var),
            Request::Conditional {
                var,
                evidence,
                burn_in,
                samples,
                no_cache,
            } => self.conditional(*var, evidence, *burn_in, *samples, *no_cache),
            Request::Status => Ok(self.status()),
            Request::Metrics => Ok(self.metrics()),
            Request::Shutdown => Ok("{\"ok\":true,\"type\":\"shutdown\"}".to_string()),
        }
    }

    fn marginal(&self, var: usize) -> Result<String> {
        let (dist, samples) = self
            .live
            .marginal(var)
            .with_context(|| format!("var {var} out of range (n = {})", self.graph.n()))?;
        Ok(format!(
            "{{\"ok\":true,\"type\":\"marginal\",\"var\":{var},\"dist\":{},\"samples\":{samples}}}",
            json_dist(&dist)
        ))
    }

    fn conditional(
        &self,
        var: usize,
        evidence: &[(usize, u16)],
        burn_in: Option<u64>,
        samples: Option<u64>,
        no_cache: bool,
    ) -> Result<String> {
        let n = self.graph.n();
        let d = self.graph.domain_size() as usize;
        if var >= n {
            bail!("var {var} out of range (n = {n})");
        }
        let mut pinned = vec![false; n];
        for &(site, val) in evidence {
            if site >= n {
                bail!("evidence site {site} out of range (n = {n})");
            }
            if (val as usize) >= d {
                bail!("evidence value {val} for site {site} out of range (D = {d})");
            }
            if pinned[site] {
                bail!("evidence pins site {site} twice");
            }
            pinned[site] = true;
        }
        let burn = burn_in.unwrap_or(self.defaults.burn_in);
        let keep = samples.unwrap_or(self.defaults.samples);
        if keep == 0 {
            bail!("samples must be >= 1 (a 0-sample conditional has no estimate)");
        }
        if burn.saturating_add(keep) > MAX_QUERY_STEPS {
            bail!(
                "burn_in + samples = {} exceeds the per-request cap of {MAX_QUERY_STEPS}",
                burn.saturating_add(keep)
            );
        }

        // Pinning the query variable makes the answer a point mass.
        if pinned[var] {
            let val = evidence
                .iter()
                .find(|(s, _)| *s == var)
                .map(|&(_, v)| v)
                .with_context(|| format!("evidence pins var {var} but carries no value for it"))?;
            let mut dist = vec![0.0; d];
            dist[val as usize] = 1.0;
            return Ok(format!(
                "{{\"ok\":true,\"type\":\"conditional\",\"var\":{var},\"dist\":{},\
                 \"samples\":0,\"burn_in\":0,\"pinned\":true}}",
                json_dist(&dist)
            ));
        }

        let key: CondKey = (evidence.to_vec(), burn, keep);
        let (result, source) = if no_cache || !self.cache_cfg.enabled {
            (self.sample_conditional(&key), "sampled")
        } else {
            self.coalesced(&key)
        };
        let dist = &result.dists[var];
        Ok(format!(
            "{{\"ok\":true,\"type\":\"conditional\",\"var\":{var},\"dist\":{},\
             \"samples\":{keep},\"burn_in\":{burn},\"source\":\"{source}\"}}",
            json_dist(dist)
        ))
    }

    /// Run one conditional chain for `key` and record marginals over
    /// every variable (pinned sites come out as point masses for free).
    /// The RNG stream is a pure function of the key and the pool seed,
    /// so identical keys always replay the identical chain — coalesced,
    /// cached, and uncached answers can't disagree.
    fn sample_conditional(&self, key: &CondKey) -> CondResult {
        let (evidence, burn, keep) = (&key.0, key.1, key.2);
        let n = self.graph.n();
        let d = self.graph.domain_size() as usize;
        let mut pinned = vec![false; n];
        for &(site, _) in evidence {
            pinned[site] = true;
        }
        let free: Vec<usize> = (0..n).filter(|&i| !pinned[i]).collect();

        // Warm start from the most advanced chain (all zeros before any
        // publish), then pin the evidence.
        let mut state = match self.live.freshest_state() {
            Some((s, _)) => s,
            None => vec![0u16; n],
        };
        for &(site, val) in evidence {
            state[site] = val;
        }

        let mut rng = Pcg64::with_stream(self.seed, 0x5EED_C0DE ^ stream_key(key));
        let mut sampler = EvidenceSampler {
            inner: self.sampler.build(&self.graph),
            free,
        };
        sampler.reset(&state, &mut rng);
        for _ in 0..burn {
            sampler.step(&mut state, &mut rng);
        }
        let mut est = MarginalEstimator::new(n, d);
        for _ in 0..keep {
            sampler.step(&mut state, &mut rng);
            est.update(&state);
        }
        self.runs_total.add(1);
        CondResult {
            dists: (0..n).map(|i| est.marginal(i)).collect(),
        }
    }

    /// Serve `key` through the cache and in-flight map: a fresh cached
    /// result returns immediately; otherwise one caller (the leader)
    /// runs the chain while everyone else blocks on the shared cell.
    /// The leader fills the cache *before* removing the in-flight entry,
    /// under one lock — so a straggler arriving at any interleaving
    /// either joins the cell or hits the cache, never recomputes.
    fn coalesced(&self, key: &CondKey) -> (CondResult, &'static str) {
        let pending = {
            let mut st = self.lock_cond();
            if let Some((at, res)) = st.cache.get(key) {
                if at.elapsed() <= self.cache_cfg.ttl {
                    self.cache_hits.add(1);
                    return (res.clone(), "cached");
                }
            }
            st.inflight
                .entry(key.clone())
                .or_insert_with(|| Arc::new(OnceLock::new()))
                .clone()
        };
        self.cache_misses.add(1);
        let mut led = false;
        let result = pending
            .get_or_init(|| {
                led = true;
                self.sample_conditional(key)
            })
            .clone();
        if led {
            let mut st = self.lock_cond();
            st.cache
                .insert(key.clone(), (Instant::now(), result.clone()));
            if st.cache.len() > self.cache_cfg.capacity {
                let ttl = self.cache_cfg.ttl;
                st.cache.retain(|_, v| v.0.elapsed() <= ttl);
            }
            while st.cache.len() > self.cache_cfg.capacity {
                match st.cache.iter().min_by_key(|(_, v)| v.0).map(|(k, _)| k.clone()) {
                    Some(oldest) => {
                        st.cache.remove(&oldest);
                    }
                    None => break,
                }
            }
            st.inflight.remove(key);
            (result, "sampled")
        } else {
            self.coalesced_total.add(1);
            (result, "coalesced")
        }
    }

    fn status(&self) -> String {
        let iters = self.live.chain_iters();
        let (rhat, ess) = self.live.diagnostics();
        let iter_toks: Vec<String> = iters.iter().map(|i| i.to_string()).collect();
        format!(
            "{{\"ok\":true,\"type\":\"status\",\"chains\":{},\"iters\":[{}],\
             \"samples\":{},\"rhat\":{},\"pooled_ess\":{},\
             \"model\":{{\"n\":{},\"d\":{},\"factors\":{}}},\"sampler\":\"{}\"}}",
            self.live.chains(),
            iter_toks.join(","),
            self.live.total_samples(),
            json_opt(rhat),
            json_opt(ess),
            self.graph.n(),
            self.graph.domain_size(),
            self.graph.num_factors(),
            esc(&self.sampler.label(&self.graph)),
        )
    }

    fn metrics(&self) -> String {
        // The exposition JSON is multi-line; raw newlines only occur as
        // token separators (strings escape theirs), so flattening them
        // to spaces keeps the document valid and the response one line.
        let snap = crate::metrics::expose::to_json(&self.hub.snapshot());
        let flat = snap.replace('\n', " ");
        format!(
            "{{\"ok\":true,\"type\":\"metrics\",\"snapshot\":{}}}",
            flat.trim()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{exact_distribution, StateSpace};
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    fn engine_over(
        g: Arc<FactorGraph>,
        chains: usize,
    ) -> (QueryEngine, Arc<LiveEstimator>, Arc<MetricsHub>) {
        let live = Arc::new(LiveEstimator::new(g.n(), g.domain_size() as usize, chains, 64));
        let hub = Arc::new(MetricsHub::new());
        let engine = QueryEngine::new(
            g,
            live.clone(),
            hub.clone(),
            SamplerSpec::Gibbs(EnergyPath::Specialized),
            11,
            QueryDefaults::default(),
            QueryCacheConfig::default(),
        );
        (engine, live, hub)
    }

    #[test]
    fn parses_requests() {
        assert_eq!(
            parse_request("{\"type\":\"marginal\",\"var\":3}").unwrap(),
            Request::Marginal { var: 3 }
        );
        let line = "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":1,\"2\":0}}";
        let req = parse_request(line).unwrap();
        assert_eq!(
            req,
            Request::Conditional {
                var: 1,
                evidence: vec![(0, 1), (2, 0)],
                burn_in: None,
                samples: None,
                no_cache: false,
            }
        );
        let line = "{\"type\":\"conditional\",\"var\":1,\"evidence\":{},\"no_cache\":true}";
        assert!(matches!(
            parse_request(line).unwrap(),
            Request::Conditional { no_cache: true, .. }
        ));
        assert!(
            parse_request("{\"type\":\"conditional\",\"var\":1,\"evidence\":{},\"no_cache\":3}")
                .is_err(),
            "non-boolean no_cache must be rejected"
        );
        assert_eq!(parse_request("{\"type\":\"status\"}").unwrap(), Request::Status);
        assert_eq!(parse_request("{\"type\":\"shutdown\"}").unwrap(), Request::Shutdown);
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{\"type\":\"nope\"}").is_err());
        assert!(parse_request("{\"type\":\"marginal\",\"var\":-1}").is_err());
        assert!(parse_request("{\"type\":\"marginal\",\"var\":1.5}").is_err());
        assert!(parse_request("{\"type\":\"conditional\",\"var\":0}").is_err());
    }

    #[test]
    fn marginal_reads_live_counts() {
        let g = Arc::new(models::tiny_random(2, 2, 0.5, 31));
        let (engine, live, _) = engine_over(g, 1);
        let mut local = crate::analysis::MarginalEstimator::new(2, 2);
        local.update(&[0, 1]);
        local.update(&[1, 1]);
        live.publish(0, &local, &[], 2, &[1, 1]);
        let resp = engine.handle(&Request::Marginal { var: 0 }).unwrap();
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"samples\":2"), "{resp}");
        assert!(resp.contains("\"dist\":[0.5,0.5]"), "{resp}");
        assert!(engine.handle(&Request::Marginal { var: 9 }).is_err());
    }

    /// The conditional sampler must converge to the exact enumerated
    /// conditional π(x_var | evidence) on a tiny model.
    #[test]
    fn conditional_matches_enumeration() {
        let g = Arc::new(models::tiny_random(4, 3, 0.9, 32));
        let (engine, _, _) = engine_over(g.clone(), 1);
        let evidence = vec![(0usize, 2u16), (3usize, 1u16)];
        let var = 1usize;

        // Exact conditional by enumeration.
        let space = StateSpace::for_graph(&g);
        let pi = exact_distribution(&g);
        let d = g.domain_size() as usize;
        let mut num = vec![0.0f64; d];
        let mut den = 0.0f64;
        for idx in 0..space.len() {
            let s = space.state(idx);
            if evidence.iter().all(|&(site, val)| s[site] == val) {
                num[s[var] as usize] += pi[idx];
                den += pi[idx];
            }
        }
        let exact: Vec<f64> = num.iter().map(|&x| x / den).collect();

        let resp = engine
            .handle(&Request::Conditional {
                var,
                evidence,
                burn_in: Some(2_000),
                samples: Some(60_000),
                no_cache: false,
            })
            .unwrap();
        // Pull the dist array back out of the response line.
        let doc = JsonValue::parse(&resp).unwrap();
        let dist: Vec<f64> = doc
            .get("dist")
            .and_then(|v| v.as_array())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (u, (&got, &want)) in dist.iter().zip(exact.iter()).enumerate() {
            assert!(
                (got - want).abs() < 0.02,
                "conditional[{u}] = {got}, exact = {want}"
            );
        }
    }

    #[test]
    fn conditional_on_pinned_var_is_point_mass() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 33));
        let (engine, _, _) = engine_over(g, 1);
        let resp = engine
            .handle(&Request::Conditional {
                var: 0,
                evidence: vec![(0, 1)],
                burn_in: None,
                samples: None,
                no_cache: false,
            })
            .unwrap();
        assert!(resp.contains("\"dist\":[0,1]"), "{resp}");
        assert!(resp.contains("\"pinned\":true"), "{resp}");
    }

    #[test]
    fn conditional_validates_evidence() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 34));
        let (engine, _, _) = engine_over(g, 1);
        let bad_site = Request::Conditional {
            var: 0,
            evidence: vec![(9, 0)],
            burn_in: None,
            samples: None,
            no_cache: false,
        };
        assert!(engine.handle(&bad_site).is_err());
        let bad_val = Request::Conditional {
            var: 0,
            evidence: vec![(1, 7)],
            burn_in: None,
            samples: None,
            no_cache: false,
        };
        assert!(engine.handle(&bad_val).is_err());
    }

    #[test]
    fn status_and_metrics_render_valid_json() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 35));
        let (engine, live, _) = engine_over(g, 2);
        let empty = crate::analysis::MarginalEstimator::new(3, 2);
        live.publish(0, &empty, &[1.0, 2.0], 10, &[0, 0, 0]);
        let (resp, shutdown) = engine.handle_line("{\"type\":\"status\"}");
        assert!(!shutdown);
        let doc = JsonValue::parse(&resp).unwrap();
        assert_eq!(doc.get("chains").and_then(|v| v.as_f64()), Some(2.0));
        assert!(!resp.contains('\n'));

        let (resp, _) = engine.handle_line("{\"type\":\"metrics\"}");
        let doc = JsonValue::parse(&resp).unwrap();
        assert!(doc.get("snapshot").is_some(), "{resp}");
        assert!(!resp.contains('\n'));

        let (resp, shutdown) = engine.handle_line("{\"type\":\"shutdown\"}");
        assert!(shutdown);
        assert!(resp.contains("\"ok\":true"));

        let (resp, shutdown) = engine.handle_line("garbage");
        assert!(!shutdown);
        assert!(resp.contains("\"ok\":false"));
    }

    /// N identical concurrent conditionals: exactly one chain runs, the
    /// other N−1 are served by the in-flight cell or the cache, and
    /// every response is bit-identical.
    #[test]
    fn identical_conditionals_coalesce_to_one_run() {
        let g = Arc::new(models::tiny_random(4, 3, 0.8, 36));
        let (engine, _, hub) = engine_over(g, 1);
        let engine = Arc::new(engine);
        let req = Request::Conditional {
            var: 1,
            evidence: vec![(0, 2)],
            burn_in: Some(300),
            samples: Some(2_000),
            no_cache: false,
        };
        let threads = 6;
        let mut handles = Vec::new();
        for _ in 0..threads {
            let engine = engine.clone();
            let req = req.clone();
            handles.push(std::thread::spawn(move || engine.handle(&req).unwrap()));
        }
        let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let dist0 = {
            let doc = JsonValue::parse(&responses[0]).unwrap();
            doc.get("dist").unwrap().clone()
        };
        for resp in &responses {
            let doc = JsonValue::parse(resp).unwrap();
            assert_eq!(
                doc.get("dist"),
                Some(&dist0),
                "coalesced/cached responses diverged: {resp}"
            );
        }
        let snap = hub.snapshot();
        assert_eq!(
            snap.counter("service_conditional_runs_total"),
            Some(1),
            "identical concurrent keys must trigger exactly one re-burn-in"
        );
        let coalesced = snap.counter("service_conditional_coalesced_total").unwrap_or(0);
        let hits = snap.counter("service_conditional_cache_hits_total").unwrap_or(0);
        assert_eq!(
            coalesced + hits,
            (threads - 1) as u64,
            "every non-leader must be coalesced or cache-served"
        );
    }

    /// The cache serves repeats bit-exactly; `no_cache` bypasses it and
    /// re-runs the chain — but the key-derived RNG stream still makes
    /// the answer identical to the cached one.
    #[test]
    fn cache_and_no_cache_agree_bit_exactly() {
        let g = Arc::new(models::tiny_random(4, 3, 0.8, 37));
        let (engine, _, hub) = engine_over(g, 1);
        let mk = |no_cache| Request::Conditional {
            var: 2,
            evidence: vec![(0, 1), (3, 2)],
            burn_in: Some(200),
            samples: Some(1_000),
            no_cache,
        };
        let first = engine.handle(&mk(false)).unwrap();
        assert!(first.contains("\"source\":\"sampled\""), "{first}");
        let second = engine.handle(&mk(false)).unwrap();
        assert!(second.contains("\"source\":\"cached\""), "{second}");
        let bypass = engine.handle(&mk(true)).unwrap();
        assert!(bypass.contains("\"source\":\"sampled\""), "{bypass}");

        let dist = |resp: &str| JsonValue::parse(resp).unwrap().get("dist").unwrap().clone();
        assert_eq!(dist(&first), dist(&second));
        assert_eq!(dist(&first), dist(&bypass), "key-derived stream must match");
        assert_eq!(
            hub.snapshot().counter("service_conditional_runs_total"),
            Some(2),
            "cached repeat must not re-run; no_cache must"
        );
    }

    /// `samples: 0` and over-cap budgets are validated errors, not
    /// silent clamps or NaN distributions.
    #[test]
    fn degenerate_budgets_are_validated() {
        let g = Arc::new(models::tiny_random(3, 2, 0.5, 38));
        let (engine, _, _) = engine_over(g, 1);
        let zero = Request::Conditional {
            var: 0,
            evidence: vec![(1, 0)],
            burn_in: None,
            samples: Some(0),
            no_cache: false,
        };
        let err = engine.handle(&zero).unwrap_err();
        assert!(format!("{err:#}").contains("samples"), "{err:#}");
        let oversized = Request::Conditional {
            var: 0,
            evidence: vec![(1, 0)],
            burn_in: Some(MAX_QUERY_STEPS),
            samples: Some(1),
            no_cache: false,
        };
        let err = engine.handle(&oversized).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "{err:#}");
        // burn_in of 0 stays valid: the warm start may already suffice.
        let warm = Request::Conditional {
            var: 0,
            evidence: vec![(1, 0)],
            burn_in: Some(0),
            samples: Some(10),
            no_cache: false,
        };
        assert!(engine.handle(&warm).is_ok());
    }
}
