//! Minimal SIGINT/SIGTERM latch, std-only.
//!
//! The handler just sets an atomic flag; the serve loop polls it and
//! performs the orderly shutdown (drain connections, flush checkpoints)
//! from normal code, keeping the handler trivially async-signal-safe.
//! On non-Unix targets installation is a no-op and the flag only ever
//! trips via [`trigger`].

use std::sync::atomic::{AtomicBool, Ordering};

static TRIGGERED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    TRIGGERED.store(true, Ordering::SeqCst);
}

/// Install handlers for SIGINT (2) and SIGTERM (15). Idempotent.
pub fn install() {
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        let handler: extern "C" fn(i32) = on_signal;
        let addr = handler as *const () as usize;
        unsafe {
            signal(2, addr); // SIGINT
            signal(15, addr); // SIGTERM
        }
    }
}

/// Has a termination signal arrived (or [`trigger`] been called)?
pub fn triggered() -> bool {
    TRIGGERED.load(Ordering::SeqCst)
}

/// Trip the flag programmatically — used by tests and by non-Unix
/// builds where no handler is installed.
pub fn trigger() {
    TRIGGERED.store(true, Ordering::SeqCst);
}

/// Clear the flag (tests; a fresh serve loop after a handled signal).
pub fn reset() {
    TRIGGERED.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latch_round_trip() {
        reset();
        assert!(!triggered());
        trigger();
        assert!(triggered());
        reset();
        assert!(!triggered());
        // Installing the handlers must not trip the flag by itself.
        install();
        assert!(!triggered());
    }
}
