//! Exact Poisson sampling.
//!
//! Two regimes, matching what NumPy does:
//!   * λ < 10:  multiplicative chop-down (Knuth) — O(λ) expected, exact.
//!   * λ ≥ 10:  Hörmann's PTRS transformed-rejection — O(1) expected, exact.
//!
//! The samplers draw `s_φ ~ Poisson(λ M_φ / Ψ)` (Eq. 2) and the sparse
//! vector sampler draws the total `B ~ Poisson(Λ)`; both paths land here.

use super::special::ln_factorial;
use super::Rng;

/// Draw one Poisson(λ) variate. λ must be finite and ≥ 0.
pub fn sample_poisson<R: Rng + ?Sized>(rng: &mut R, lambda: f64) -> u64 {
    debug_assert!(lambda >= 0.0 && lambda.is_finite());
    if lambda == 0.0 {
        0
    } else if lambda < 10.0 {
        poisson_knuth(rng, lambda)
    } else {
        poisson_ptrs(rng, lambda)
    }
}

/// Knuth's product-of-uniforms method (exact for small λ).
fn poisson_knuth<R: Rng + ?Sized>(rng: &mut R, lambda: f64) -> u64 {
    let l = (-lambda).exp();
    let mut k = 0u64;
    let mut p = 1.0f64;
    loop {
        p *= rng.f64_open();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// Hörmann's PTRS (transformed rejection with squeeze), exact for λ ≥ 10.
/// Constants follow Hörmann (1993) as used in NumPy's `random_poisson_ptrs`.
fn poisson_ptrs<R: Rng + ?Sized>(rng: &mut R, lambda: f64) -> u64 {
    let slam = lambda.sqrt();
    let loglam = lambda.ln();
    let b = 0.931 + 2.53 * slam;
    let a = -0.059 + 0.024_83 * b;
    let invalpha = 1.1239 + 1.1328 / (b - 3.4);
    let vr = 0.9277 - 3.6224 / (b - 2.0);

    loop {
        let u = rng.f64() - 0.5;
        let v = rng.f64_open();
        let us = 0.5 - u.abs();
        let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
        if us >= 0.07 && v <= vr {
            return k as u64;
        }
        if k < 0.0 || (us < 0.013 && v > us) {
            continue;
        }
        let lhs = v.ln() + invalpha.ln() - (a / (us * us) + b).ln();
        let rhs = k * loglam - lambda - ln_factorial(k as u64);
        if lhs <= rhs {
            return k as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn moments(lambda: f64, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Pcg64::seeded(seed);
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let k = sample_poisson(&mut rng, lambda) as f64;
            sum += k;
            sumsq += k * k;
        }
        let mean = sum / n as f64;
        (mean, sumsq / n as f64 - mean * mean)
    }

    #[test]
    fn zero_lambda() {
        let mut rng = Pcg64::seeded(0);
        for _ in 0..100 {
            assert_eq!(sample_poisson(&mut rng, 0.0), 0);
        }
    }

    #[test]
    fn small_lambda_mean_var() {
        for &lam in &[0.01, 0.3, 1.0, 4.5, 9.9] {
            let (mean, var) = moments(lam, 200_000, 11);
            let tol = 4.0 * (lam / 200_000f64).sqrt() + 0.01;
            assert!((mean - lam).abs() < tol, "λ={lam}: mean={mean}");
            assert!((var - lam).abs() < 12.0 * tol, "λ={lam}: var={var}");
        }
    }

    #[test]
    fn large_lambda_mean_var() {
        for &lam in &[10.0, 35.0, 173.0, 1000.0] {
            let (mean, var) = moments(lam, 200_000, 13);
            let setol = 5.0 * (lam / 200_000f64).sqrt();
            assert!((mean - lam).abs() < setol, "λ={lam}: mean={mean}");
            assert!((var / lam - 1.0).abs() < 0.05, "λ={lam}: var={var}");
        }
    }

    #[test]
    fn small_lambda_pmf_chi2() {
        // Compare the empirical distribution at λ=3 against the exact pmf
        // over k=0..=10 (+ tail bucket) with a chi-squared test.
        let lam = 3.0;
        let n = 300_000usize;
        let mut rng = Pcg64::seeded(17);
        let mut counts = [0u64; 12];
        for _ in 0..n {
            let k = sample_poisson(&mut rng, lam) as usize;
            counts[k.min(11)] += 1;
        }
        let mut pmf = [0.0f64; 12];
        let mut acc = (-lam).exp();
        let mut total = 0.0;
        for (k, p) in pmf.iter_mut().enumerate().take(11) {
            *p = acc;
            total += acc;
            acc *= lam / (k as f64 + 1.0);
        }
        pmf[11] = 1.0 - total;
        let chi2: f64 = counts
            .iter()
            .zip(pmf.iter())
            .map(|(&c, &p)| {
                let e = p * n as f64;
                (c as f64 - e).powi(2) / e
            })
            .sum();
        // df = 11, crit at alpha=1e-4 ≈ 39.9; generous bound.
        assert!(chi2 < 55.0, "chi2 = {chi2}");
    }

    #[test]
    fn ptrs_pmf_chi2_lambda_20() {
        // Exact-distribution check in the PTRS regime: bucket k into
        // [0,12), [12,16), [16,20), [20,24), [24,28), [28,..).
        let lam = 20.0;
        let n = 300_000usize;
        let mut rng = Pcg64::seeded(19);
        let edges = [12u64, 16, 20, 24, 28];
        let mut counts = [0u64; 6];
        for _ in 0..n {
            let k = sample_poisson(&mut rng, lam);
            let b = edges.iter().position(|&e| k < e).unwrap_or(5);
            counts[b] += 1;
        }
        // Exact bucket probabilities.
        let mut pmf_k = vec![0.0f64; 200];
        let mut acc = (-lam).exp();
        for (k, slot) in pmf_k.iter_mut().enumerate() {
            *slot = acc;
            acc *= lam / (k as f64 + 1.0);
        }
        let bucket = |lo: usize, hi: usize| pmf_k[lo..hi].iter().sum::<f64>();
        let probs = [
            bucket(0, 12),
            bucket(12, 16),
            bucket(16, 20),
            bucket(20, 24),
            bucket(24, 28),
            1.0 - bucket(0, 28),
        ];
        let chi2: f64 = counts
            .iter()
            .zip(probs.iter())
            .map(|(&c, &p)| {
                let e = p * n as f64;
                (c as f64 - e).powi(2) / e
            })
            .sum();
        // df = 5, crit at alpha=1e-4 ≈ 25.7; generous bound.
        assert!(chi2 < 40.0, "chi2 = {chi2}");
    }
}
