//! The paper's O(λ) sparse Poisson-vector sampler (§3, footnote 7).
//!
//! To draw `s_φ ~ Poisson(λ_φ)` independently for up to Δ (or |Φ|) factors
//! without paying O(Δ) per iteration, use the decomposition
//!
//! ```text
//! B = Σ_φ s_φ  ~  Poisson(Λ),   Λ = Σ_φ λ_φ
//! (s_φ | B)    ~  Multinomial(B, (λ_φ / Λ)_φ)
//! ```
//!
//! Sample `B` once, then make `B` O(1) alias-table picks. Expected time
//! O(Λ) = O(λ) after an O(m) one-time setup per factor set — this is what
//! lets MGPMH/DoubleMIN-Gibbs hit their Table-1 complexity.
//!
//! The output is sparse: a list of (index, count) pairs touching only the
//! factors that were actually hit. A dense scratch array + touched list
//! keeps accumulation O(B) with no hashing.

use super::{sample_poisson, AliasTable, Rng};

/// Reusable sampler for a fixed vector of Poisson rates.
///
/// Two regimes, picked automatically:
/// * Λ ≲ m: the O(Λ) decomposition above (alias-table multinomial split).
/// * Λ ≳ m: per-outcome direct Poisson draws — O(m) beats O(Λ) once the
///   expected trial count exceeds the outcome count. `exp(−λ_φ)` is
///   precomputed per outcome so the small-rate draws are branch-cheap.
#[derive(Clone, Debug)]
pub struct SparsePoissonSampler {
    table: AliasTable,
    lambda_total: f64,
    rates: Vec<f64>,
    exp_neg_rates: Vec<f64>, // exp(−λ_φ), used by the direct path
    counts: Vec<u32>,        // dense scratch, zeroed between draws
    touched: Vec<u32>,       // indices with counts > 0 this draw
}

impl SparsePoissonSampler {
    /// Build from per-outcome rates λ_φ (must not all be zero).
    pub fn new(rates: &[f64]) -> Self {
        let table = AliasTable::new(rates);
        let lambda_total = table.total_weight();
        let exp_neg_rates = rates.iter().map(|&r| (-r).exp()).collect();
        Self {
            table,
            lambda_total,
            rates: rates.to_vec(),
            exp_neg_rates,
            counts: vec![0; rates.len()],
            touched: Vec::new(),
        }
    }

    /// Total rate Λ = Σ λ_φ (the expected number of trials per draw).
    pub fn lambda_total(&self) -> f64 {
        self.lambda_total
    }

    /// Number of outcomes m.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    /// True if there are no outcomes (never: construction asserts).
    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Draw the sparse vector; `f(index, count)` is called once per
    /// outcome with count > 0. Expected cost O(min(Λ, m)).
    pub fn sample_into<R: Rng + ?Sized, F: FnMut(usize, u32)>(
        &mut self,
        rng: &mut R,
        mut f: F,
    ) -> u64 {
        if self.lambda_total > 0.75 * self.counts.len() as f64 {
            return self.sample_direct(rng, f);
        }
        let b = sample_poisson(rng, self.lambda_total);
        for _ in 0..b {
            let idx = self.table.sample(rng);
            if self.counts[idx] == 0 {
                self.touched.push(idx as u32);
            }
            self.counts[idx] += 1;
        }
        for &idx in &self.touched {
            f(idx as usize, self.counts[idx as usize]);
            self.counts[idx as usize] = 0;
        }
        self.touched.clear();
        b
    }

    /// Direct path for Λ ≳ m: draw each s_φ independently in O(m). Uses
    /// the precomputed exp(−λ_φ) for an allocation- and exp-free inner
    /// loop in the (dominant) small-rate case.
    fn sample_direct<R: Rng + ?Sized, F: FnMut(usize, u32)>(
        &mut self,
        rng: &mut R,
        mut f: F,
    ) -> u64 {
        let mut total = 0u64;
        for idx in 0..self.rates.len() {
            let rate = self.rates[idx];
            if rate == 0.0 {
                continue;
            }
            let s = if rate < 10.0 {
                // inlined Knuth chop-down with cached exp(−rate)
                let l = self.exp_neg_rates[idx];
                let mut k = 0u32;
                let mut p = rng.f64_open();
                while p > l {
                    p *= rng.f64_open();
                    k += 1;
                }
                k
            } else {
                sample_poisson(rng, rate) as u32
            };
            if s > 0 {
                f(idx, s);
                total += s as u64;
            }
        }
        total
    }

    /// Convenience: collect the sparse draw into a vector of (idx, count).
    pub fn sample_vec<R: Rng + ?Sized>(&mut self, rng: &mut R) -> Vec<(usize, u32)> {
        let mut out = Vec::new();
        self.sample_into(rng, |i, c| out.push((i, c)));
        out
    }

    /// Trial-level draw: `f(index)` is called once per *trial* (an index
    /// hit k times gets k calls) instead of once per distinct index.
    ///
    /// For linear consumers — anything of the form Σ_φ s_φ·g(φ), like the
    /// Eq. (2) estimator — this is equivalent to [`Self::sample_into`]
    /// but skips the dedup scratch entirely, avoiding two random-access
    /// arrays per trial (a measurable cache win on large factor sets; see
    /// EXPERIMENTS.md §Perf). Falls back to the O(m) direct path when
    /// Λ ≳ m, where dedup is free.
    pub fn sample_trials<R: Rng + ?Sized, F: FnMut(usize, u32)>(
        &mut self,
        rng: &mut R,
        mut f: F,
    ) -> u64 {
        if self.lambda_total > 0.75 * self.counts.len() as f64 {
            return self.sample_direct(rng, f);
        }
        let b = sample_poisson(rng, self.lambda_total);
        for _ in 0..b {
            f(self.table.sample(rng), 1);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn marginals_match_independent_poissons() {
        // Each s_φ must be marginally Poisson(λ_φ): check mean and variance.
        let rates = [0.05, 0.3, 1.2, 0.0, 2.5];
        let mut s = SparsePoissonSampler::new(&rates);
        let mut rng = Pcg64::seeded(41);
        let n = 200_000;
        let mut sums = [0.0f64; 5];
        let mut sumsq = [0.0f64; 5];
        for _ in 0..n {
            let mut draw = [0.0f64; 5];
            s.sample_into(&mut rng, |i, c| draw[i] = c as f64);
            for i in 0..5 {
                sums[i] += draw[i];
                sumsq[i] += draw[i] * draw[i];
            }
        }
        for i in 0..5 {
            let mean = sums[i] / n as f64;
            let var = sumsq[i] / n as f64 - mean * mean;
            let tol = 4.0 * (rates[i].max(0.01) / n as f64).sqrt() + 0.005;
            assert!((mean - rates[i]).abs() < tol, "i={i} mean={mean}");
            assert!((var - rates[i]).abs() < 20.0 * tol, "i={i} var={var}");
        }
    }

    #[test]
    fn zero_rate_never_drawn() {
        let mut s = SparsePoissonSampler::new(&[1.0, 0.0, 1.0]);
        let mut rng = Pcg64::seeded(42);
        for _ in 0..20_000 {
            s.sample_into(&mut rng, |i, _| assert_ne!(i, 1));
        }
    }

    #[test]
    fn total_is_poisson_lambda_total() {
        let rates = [0.5, 0.25, 0.25];
        let mut s = SparsePoissonSampler::new(&rates);
        assert!((s.lambda_total() - 1.0).abs() < 1e-12);
        let mut rng = Pcg64::seeded(43);
        let n = 200_000;
        let mut total = 0u64;
        for _ in 0..n {
            total += s.sample_into(&mut rng, |_, _| {});
        }
        let mean = total as f64 / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn scratch_is_clean_between_draws() {
        // Internal counts must be reset: two consecutive draws with the
        // same RNG state would otherwise leak counts.
        let mut s = SparsePoissonSampler::new(&[3.0, 3.0]);
        let mut rng = Pcg64::seeded(44);
        for _ in 0..1000 {
            let v = s.sample_vec(&mut rng);
            let total: u32 = v.iter().map(|&(_, c)| c).sum();
            let b: u64 = total as u64;
            // Re-derive: sample_into returned b == sum of counts.
            assert!(v.iter().all(|&(_, c)| c > 0));
            let _ = b;
        }
        assert!(s.counts.iter().all(|&c| c == 0));
        assert!(s.touched.is_empty());
    }

    #[test]
    fn direct_path_marginals() {
        // Λ = 27 ≫ m = 3 forces the O(m) direct path; marginals must be
        // the same independent Poissons.
        let rates = [20.0, 7.0, 0.0];
        let mut s = SparsePoissonSampler::new(&rates);
        let mut rng = Pcg64::seeded(46);
        let n = 100_000;
        let mut sums = [0.0f64; 3];
        let mut sumsq = [0.0f64; 3];
        for _ in 0..n {
            let mut d = [0.0f64; 3];
            let total = s.sample_into(&mut rng, |i, c| d[i] = c as f64);
            assert_eq!(total, (d[0] + d[1] + d[2]) as u64);
            for i in 0..3 {
                sums[i] += d[i];
                sumsq[i] += d[i] * d[i];
            }
        }
        for i in 0..3 {
            let mean = sums[i] / n as f64;
            let var = sumsq[i] / n as f64 - mean * mean;
            let tol = 5.0 * (rates[i].max(0.01) / n as f64).sqrt() + 0.01;
            assert!((mean - rates[i]).abs() < tol, "i={i} mean={mean}");
            assert!((var - rates[i]).abs() < 30.0 * tol, "i={i} var={var}");
        }
    }

    #[test]
    fn both_paths_same_distribution() {
        // Same rates, forced through both paths (by scaling m with zero-
        // rate padding), must produce matching moments.
        let base = vec![1.5, 0.5, 2.0];
        let mut padded = base.clone();
        padded.extend(std::iter::repeat(0.0).take(50)); // Λ=4 < 0.75·53 -> alias path
        let mut s_direct = SparsePoissonSampler::new(&base); // Λ=4 > 2.25 -> direct
        let mut s_alias = SparsePoissonSampler::new(&padded);
        let mut rng1 = Pcg64::seeded(47);
        let mut rng2 = Pcg64::seeded(48);
        let n = 150_000;
        let (mut m1, mut m2) = ([0.0f64; 3], [0.0f64; 3]);
        for _ in 0..n {
            s_direct.sample_into(&mut rng1, |i, c| m1[i] += c as f64);
            s_alias.sample_into(&mut rng2, |i, c| {
                if i < 3 {
                    m2[i] += c as f64;
                }
            });
        }
        for i in 0..3 {
            let a = m1[i] / n as f64;
            let b = m2[i] / n as f64;
            assert!((a - b).abs() < 0.03, "i={i}: {a} vs {b}");
            assert!((a - base[i]).abs() < 0.03, "i={i}: {a} vs rate");
        }
    }

    #[test]
    fn pairwise_independence_covariance() {
        // Independent Poissons have zero covariance; the multinomial split
        // conditioned on B reproduces that marginally.
        let rates = [1.0, 2.0];
        let mut s = SparsePoissonSampler::new(&rates);
        let mut rng = Pcg64::seeded(45);
        let n = 300_000;
        let (mut sx, mut sy, mut sxy) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let mut d = [0.0f64; 2];
            s.sample_into(&mut rng, |i, c| d[i] = c as f64);
            sx += d[0];
            sy += d[1];
            sxy += d[0] * d[1];
        }
        let cov = sxy / n as f64 - (sx / n as f64) * (sy / n as f64);
        assert!(cov.abs() < 0.02, "cov={cov}");
    }
}
