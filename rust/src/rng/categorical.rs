//! Categorical sampling from unnormalized energies: ρ(v) ∝ exp(ε_v).
//!
//! Every Gibbs variant ends its iteration with this draw (Algorithm 1's
//! "construct distribution ρ ... sample v from ρ"). Numerically stabilized
//! with the usual max-subtraction; D is small (2–1000), so a linear CDF
//! scan beats building an alias table per iteration.

use super::Rng;

/// In-place softmax over energies: `probs[v] = exp(e_v - max) / Z`.
/// Returns the normalizer `Z` (of the shifted weights).
pub fn softmax_from_energies(energies: &[f64], probs: &mut Vec<f64>) -> f64 {
    probs.clear();
    probs.extend_from_slice(energies);
    let max = probs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for p in probs.iter_mut() {
        *p = (*p - max).exp();
        z += *p;
    }
    for p in probs.iter_mut() {
        *p /= z;
    }
    z
}

/// Sample v ~ ρ where ρ(v) ∝ exp(energies[v]). O(D), allocation-free.
#[inline]
pub fn sample_categorical_from_energies<R: Rng + ?Sized>(
    rng: &mut R,
    energies: &[f64],
) -> usize {
    debug_assert!(!energies.is_empty());
    let max = energies.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for &e in energies {
        z += (e - max).exp();
    }
    let target = rng.f64() * z;
    let mut acc = 0.0;
    for (v, &e) in energies.iter().enumerate() {
        acc += (e - max).exp();
        if target < acc {
            return v;
        }
    }
    energies.len() - 1 // floating-point edge: return the last value
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn softmax_normalizes() {
        let mut probs = Vec::new();
        softmax_from_energies(&[1.0, 2.0, 3.0], &mut probs);
        let sum: f64 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(probs[2] > probs[1] && probs[1] > probs[0]);
    }

    #[test]
    fn softmax_handles_huge_energies() {
        let mut probs = Vec::new();
        softmax_from_energies(&[1e4, 1e4 + 1.0], &mut probs);
        assert!(probs.iter().all(|p| p.is_finite()));
        let want = 1.0 / (1.0 + 1f64.exp());
        assert!((probs[0] - want).abs() < 1e-12);
    }

    #[test]
    fn sample_matches_softmax() {
        let energies = [0.0, 1.0, -0.5, 2.0];
        let mut probs = Vec::new();
        softmax_from_energies(&energies, &mut probs);
        let mut rng = Pcg64::seeded(31);
        let n = 500_000;
        let mut counts = [0u64; 4];
        for _ in 0..n {
            counts[sample_categorical_from_energies(&mut rng, &energies)] += 1;
        }
        for (v, &c) in counts.iter().enumerate() {
            let f = c as f64 / n as f64;
            assert!((f - probs[v]).abs() < 0.004, "v={v} f={f} p={}", probs[v]);
        }
    }

    #[test]
    fn deterministic_when_one_dominates() {
        let mut rng = Pcg64::seeded(32);
        for _ in 0..100 {
            let v = sample_categorical_from_energies(&mut rng, &[0.0, 200.0, 0.0]);
            assert_eq!(v, 1);
        }
    }

    #[test]
    fn uniform_when_equal() {
        let mut rng = Pcg64::seeded(33);
        let n = 300_000;
        let mut counts = [0u64; 5];
        for _ in 0..n {
            counts[sample_categorical_from_energies(&mut rng, &[7.0; 5])] += 1;
        }
        for &c in &counts {
            let f = c as f64 / n as f64;
            assert!((f - 0.2).abs() < 0.005);
        }
    }

    #[test]
    fn single_value() {
        let mut rng = Pcg64::seeded(34);
        assert_eq!(sample_categorical_from_energies(&mut rng, &[3.0]), 0);
    }
}
