//! Walker/Vose alias method: O(1) sampling from a fixed discrete
//! distribution after O(m) setup.
//!
//! The sparse Poisson-vector sampler multinomial-splits `B` trials over the
//! per-factor probabilities `p_φ = M_φ / Ψ`; the alias table makes each of
//! the `B` picks O(1), which is what gives the paper's O(λ) total
//! (§3, footnote 7). Tables are built once per graph and reused.

use super::Rng;

/// Alias table over `m` outcomes with probabilities ∝ `weights`.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,  // threshold in [0,1] for keeping the slot's own index
    alias: Vec<u32>, // fallback index per slot
    total: f64,      // sum of the input weights (callers reuse it as Λ)
}

impl AliasTable {
    /// Build from non-negative weights; at least one weight must be > 0.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "alias table needs >= 1 outcome");
        let m = weights.len();
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && weights.iter().all(|&w| w >= 0.0 && w.is_finite()),
            "weights must be non-negative, finite, not all zero"
        );

        // Vose's stable partition into small/large stacks.
        let scale = m as f64 / total;
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * scale).collect();
        let mut prob = vec![0.0f64; m];
        let mut alias = vec![0u32; m];
        let mut small: Vec<u32> = Vec::with_capacity(m);
        let mut large: Vec<u32> = Vec::with_capacity(m);
        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Numerical leftovers keep their own index with certainty.
        for &s in small.iter().chain(large.iter()) {
            prob[s as usize] = 1.0;
            alias[s as usize] = s;
        }
        Self { prob, alias, total }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True if the table has no outcomes (never: `new` asserts non-empty).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Sum of the weights the table was built from.
    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Draw one outcome index in O(1).
    #[inline]
    pub fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> usize {
        let slot = rng.index(self.prob.len());
        if rng.f64() < self.prob[slot] {
            slot
        } else {
            self.alias[slot] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn empirical(weights: &[f64], n: usize, seed: u64) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Pcg64::seeded(seed);
        let mut counts = vec![0u64; weights.len()];
        for _ in 0..n {
            counts[table.sample(&mut rng)] += 1;
        }
        counts.iter().map(|&c| c as f64 / n as f64).collect()
    }

    #[test]
    fn uniform_weights() {
        let freq = empirical(&[1.0; 8], 400_000, 21);
        for &f in &freq {
            assert!((f - 0.125).abs() < 0.005, "{freq:?}");
        }
    }

    #[test]
    fn skewed_weights() {
        let w = [0.1, 0.0, 3.0, 0.4, 10.0, 0.001];
        let total: f64 = w.iter().sum();
        let freq = empirical(&w, 1_000_000, 22);
        for (i, (&f, &wi)) in freq.iter().zip(w.iter()).enumerate() {
            let p = wi / total;
            assert!((f - p).abs() < 0.004, "i={i} f={f} p={p}");
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let freq = empirical(&[1.0, 0.0, 1.0], 100_000, 23);
        assert_eq!(freq[1], 0.0);
    }

    #[test]
    fn single_outcome() {
        let freq = empirical(&[5.0], 1000, 24);
        assert_eq!(freq[0], 1.0);
    }

    #[test]
    fn total_weight_recorded() {
        let t = AliasTable::new(&[1.5, 2.5]);
        assert!((t.total_weight() - 4.0).abs() < 1e-12);
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_all_zero() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    fn property_many_random_tables() {
        // Seeded sweep standing in for proptest: random weight vectors of
        // random sizes must produce empirical frequencies matching the
        // normalized weights.
        let mut meta = Pcg64::seeded(99);
        use crate::rng::Rng;
        for trial in 0..10 {
            let m = 2 + meta.index(40);
            let weights: Vec<f64> = (0..m).map(|_| meta.f64() * 3.0).collect();
            let total: f64 = weights.iter().sum();
            if total <= 0.0 {
                continue;
            }
            let freq = empirical(&weights, 200_000, 1000 + trial);
            for (i, (&f, &w)) in freq.iter().zip(weights.iter()).enumerate() {
                let p = w / total;
                assert!(
                    (f - p).abs() < 0.01,
                    "trial={trial} i={i} f={f} p={p}"
                );
            }
        }
    }
}
