//! Deterministic random-number stack for the samplers.
//!
//! Everything the samplers draw — uniforms, categoricals, Poissons, and the
//! paper's O(λ) sparse Poisson-vector trick (§3, footnote 7) — lives here,
//! built on a splittable PCG64 generator so that every chain in the
//! coordinator gets an independent, reproducible stream.

pub mod alias;
pub mod categorical;
pub mod pcg;
pub mod poisson;
pub mod sparse_poisson;
pub mod special;

pub use alias::AliasTable;
pub use categorical::{sample_categorical_from_energies, softmax_from_energies};
pub use pcg::Pcg64;
pub use poisson::sample_poisson;
pub use sparse_poisson::SparsePoissonSampler;

/// Minimal RNG interface used throughout the crate.
///
/// Implemented by [`Pcg64`]; kept as a trait so tests can substitute
/// counting/recording generators.
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1) on the dyadic grid.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as a `ln()` argument.
    #[inline]
    fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform index in `[0, n)`.
    #[inline]
    fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            let w = rng.f64_open();
            assert!(w > 0.0 && w <= 1.0);
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_uniformity_chi2() {
        // chi^2 over 16 buckets, 160k draws; crit value for df=15 at
        // alpha=1e-4 is ~44.3. Generous threshold to avoid flakes.
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            counts[rng.below(16) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        let chi2: f64 = counts
            .iter()
            .map(|&c| (c as f64 - expect).powi(2) / expect)
            .sum();
        assert!(chi2 < 60.0, "chi2 = {chi2}");
    }

    #[test]
    fn bernoulli_mean() {
        let mut rng = Pcg64::seeded(4);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        let mean = hits as f64 / 100_000.0;
        assert!((mean - 0.3).abs() < 0.01, "mean = {mean}");
    }
}
