//! Special functions needed by the exact samplers: ln Γ and ln k!.
//!
//! `ln_gamma` uses the Lanczos approximation (g = 7, n = 9 coefficients),
//! accurate to ~1e-13 relative over the positive reals — more than enough
//! for the PTRS Poisson acceptance test. `ln_factorial` additionally caches
//! small values exactly.

/// Lanczos coefficients for g = 7.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_6,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_572e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of the Gamma function for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    debug_assert!(x > 0.0, "ln_gamma requires x > 0, got {x}");
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + LANCZOS_G + 0.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

const FACT_TABLE_LEN: usize = 128;

fn fact_table() -> &'static [f64; FACT_TABLE_LEN] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[f64; FACT_TABLE_LEN]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0.0; FACT_TABLE_LEN];
        let mut acc = 0.0f64;
        for (k, slot) in t.iter_mut().enumerate() {
            if k > 0 {
                acc += (k as f64).ln();
            }
            *slot = acc;
        }
        t
    })
}

/// ln(k!) — table-exact for k < 128, Lanczos ln Γ(k+1) beyond.
pub fn ln_factorial(k: u64) -> f64 {
    if (k as usize) < FACT_TABLE_LEN {
        fact_table()[k as usize]
    } else {
        ln_gamma(k as f64 + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_integers() {
        // Γ(n) = (n-1)!
        let mut fact = 1.0f64;
        for n in 1..20u64 {
            if n > 1 {
                fact *= (n - 1) as f64;
            }
            let got = ln_gamma(n as f64);
            assert!(
                (got - fact.ln()).abs() < 1e-10,
                "n={n} got={got} want={}",
                fact.ln()
            );
        }
    }

    #[test]
    fn gamma_half() {
        // Γ(1/2) = sqrt(π)
        let want = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - want).abs() < 1e-12);
        // Γ(3/2) = sqrt(π)/2
        let want = (std::f64::consts::PI.sqrt() / 2.0).ln();
        assert!((ln_gamma(1.5) - want).abs() < 1e-12);
    }

    #[test]
    fn factorial_consistency() {
        for k in 0..300u64 {
            let got = ln_factorial(k);
            let want = ln_gamma(k as f64 + 1.0);
            assert!((got - want).abs() < 1e-9, "k={k}: {got} vs {want}");
        }
    }

    #[test]
    fn factorial_small_exact() {
        assert_eq!(ln_factorial(0), 0.0);
        assert_eq!(ln_factorial(1), 0.0);
        assert!((ln_factorial(5) - 120f64.ln()).abs() < 1e-12);
        assert!((ln_factorial(10) - 3_628_800f64.ln()).abs() < 1e-11);
    }
}
