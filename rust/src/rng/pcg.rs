//! PCG-XSL-RR 128/64: the crate's base generator.
//!
//! Chosen for speed (one 128-bit multiply-add per draw), statistical quality
//! and cheap *stream splitting*: any odd increment selects an independent
//! sequence, which is how the coordinator hands each chain its own stream.

use super::Rng;

const MULTIPLIER: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const DEFAULT_INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

/// PCG-XSL-RR 128/64 generator state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // always odd
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// Create a generator on stream `stream` (independent per stream id).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        // Mix the inputs through splitmix64 so close seeds/streams map to
        // distant internal states.
        let s0 = splitmix64(seed);
        let s1 = splitmix64(s0 ^ 0x9e37_79b9_7f4a_7c15);
        let t0 = splitmix64(stream.wrapping_add(0xd1b5_4a32_d192_ed03));
        let t1 = splitmix64(t0 ^ 0x94d0_49bb_1331_11eb);
        let inc = (((t0 as u128) << 64 | t1 as u128) << 1) | 1;
        let mut rng = Self {
            state: (s0 as u128) << 64 | s1 as u128,
            inc: inc ^ DEFAULT_INC & !1 | 1,
        };
        // Standard PCG initialization: advance once, add seed, advance.
        rng.step();
        rng.state = rng.state.wrapping_add((seed as u128) << 32);
        rng.step();
        rng
    }

    /// Derive a child generator for worker `id` — an independent stream
    /// seeded from this generator. Used by the coordinator to fan out
    /// reproducible per-chain RNGs.
    pub fn split(&mut self, id: u64) -> Pcg64 {
        let seed = self.next_u64();
        Pcg64::with_stream(seed, id.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ seed.rotate_left(17))
    }

    /// The full generator position `(state, inc)` — everything needed to
    /// reconstruct the stream exactly. Checkpoints persist this so a
    /// resumed chain is a bit-exact replay of the uninterrupted run.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact position captured by
    /// [`Pcg64::state_parts`]. The increment is forced odd (a PCG stream
    /// invariant) in case the parts came from a hand-edited checkpoint.
    pub fn from_state_parts(state: u128, inc: u128) -> Self {
        Self {
            state,
            inc: inc | 1,
        }
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_independent_sequences() {
        let mut a = Pcg64::with_stream(7, 0);
        let mut b = Pcg64::with_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_children_differ_from_parent_and_each_other() {
        let mut root = Pcg64::seeded(9);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let mut c1b = c1.clone();
        assert_eq!(c1.next_u64(), c1b.next_u64());
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    /// A generator rebuilt from `state_parts` continues the exact output
    /// sequence from the capture point.
    #[test]
    fn state_parts_roundtrip_continues_stream() {
        let mut a = Pcg64::with_stream(11, 3);
        for _ in 0..57 {
            a.next_u64();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg64::from_state_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bit_balance() {
        // Each of the 64 output bits should be ~50% ones.
        let mut rng = Pcg64::seeded(1234);
        let n = 20_000;
        let mut ones = [0u32; 64];
        for _ in 0..n {
            let v = rng.next_u64();
            for (b, o) in ones.iter_mut().enumerate() {
                *o += ((v >> b) & 1) as u32;
            }
        }
        for (b, &o) in ones.iter().enumerate() {
            let frac = o as f64 / n as f64;
            assert!((frac - 0.5).abs() < 0.02, "bit {b}: {frac}");
        }
    }
}
