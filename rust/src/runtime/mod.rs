//! Execution runtimes: the PJRT/XLA kernel path and the within-chain
//! parallel executor.
//!
//! * [`parallel`] — the chromatic sweep engine: a scoped `std::thread`
//!   worker pool that resamples one color class at a time on top of the
//!   site-addressable sampler surface (no accelerator involved).
//!
//! The remaining submodules form the PJRT runtime, which loads the
//! AOT-compiled HLO artifacts produced by `make artifacts` and executes
//! them from the Rust request path:
//!
//! * [`executor`] — the generic loader: artifact manifest, HLO-text →
//!   `XlaComputation` → compiled `PjRtLoadedExecutable`, typed run calls.
//! * [`backend`] — the dense-model energy backend built on top: one-hot
//!   encoding, device-resident interaction matrices, and the
//!   native-vs-XLA parity checks.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and python/compile/aot.py).

pub mod backend;
pub mod executor;
pub mod parallel;
pub mod sampler;

pub use backend::XlaDenseBackend;
pub use executor::{ArtifactStore, LoadedKernel, XlaExecutor};
pub use parallel::{ChromaticSweepEngine, SweepCtx};
pub use sampler::XlaGibbsSampler;
