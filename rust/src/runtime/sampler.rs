//! A Gibbs sampler whose conditional energies are computed by the
//! AOT-compiled Pallas/JAX kernel on the PJRT client — the full
//! L1 → L2 → L3 request path exercised per sampling step.
//!
//! Per step the backend computes the whole n×D conditional-energy table
//! (one MXU matmul); the sampler consumes the row of the variable being
//! resampled. That row never depends on the variable's own value, so the
//! update is *exactly* Algorithm 1 — the chain is statistically identical
//! to the native [`crate::samplers::GibbsSampler`] (only the floating-
//! point precision differs: f32 on the device vs f64 native).
//!
//! Throughput note: a PJRT round trip per single-site update is dominated
//! by dispatch + host↔device copies (~100 µs), so this sampler exists for
//! integration validation and as the hook for batched/sweep execution —
//! not as the fast path. `hotpath -- --xla` measures the overhead.

use crate::rng::{sample_categorical_from_energies, Rng};
use crate::samplers::{Sampler, StepStats};

use super::backend::XlaDenseBackend;

/// Gibbs sampling with XLA-computed conditional energies.
pub struct XlaGibbsSampler {
    backend: XlaDenseBackend,
    eps: Vec<f64>,
}

impl XlaGibbsSampler {
    /// Wrap a dense-model backend.
    pub fn new(backend: XlaDenseBackend) -> Self {
        let d = backend.d();
        Self {
            backend,
            eps: vec![0.0; d],
        }
    }

    /// The wrapped backend.
    pub fn backend(&self) -> &XlaDenseBackend {
        &self.backend
    }
}

impl Sampler for XlaGibbsSampler {
    // Stays non-site-local: each update computes the whole n×D table on
    // the device, so concurrent per-site dispatch would multiply, not
    // share, that work.
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let n = self.backend.n();
        let d = self.backend.d();
        let table = self
            .backend
            .cond_energies_all(state)
            .expect("XLA conditional-energy kernel failed");
        for u in 0..d {
            self.eps[u] = table[i * d + u] as f64;
        }
        let v = sample_categorical_from_energies(rng, &self.eps);
        state[i] = v as u16;
        StepStats {
            variable: i,
            // one n×D matmul = n·D multiply-accumulates ≈ Δ·D factor
            // evaluations of work on the device; report the paper unit.
            factor_evals: (n - 1) as u64 * d as u64,
            accepted: true,
        }
    }

    fn name(&self) -> &'static str {
        "xla-gibbs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;
    use crate::runtime::ArtifactStore;
    use std::path::PathBuf;

    #[test]
    fn xla_gibbs_runs_and_matches_native_conditionals() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let store = ArtifactStore::open(&dir).unwrap();
        let model = models::paper_potts();
        let backend = XlaDenseBackend::new(&store, &model).unwrap();
        let mut sampler = XlaGibbsSampler::new(backend);
        let mut rng = Pcg64::seeded(3);
        let mut state = vec![0u16; model.graph.n()];
        for _ in 0..20 {
            let st = sampler.step(&mut state, &mut rng);
            assert!(st.variable < model.graph.n());
            assert!(state.iter().all(|&v| v < 10));
        }
    }
}
