//! Artifact loading and execution over the PJRT CPU client.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::json::JsonValue;

/// The `artifacts/` directory and its parsed manifest.
#[derive(Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
    manifest: JsonValue,
}

impl ArtifactStore {
    /// Open a directory produced by `make artifacts`.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = JsonValue::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", manifest_path.display()))?;
        Ok(Self {
            dir: dir.to_path_buf(),
            manifest,
        })
    }

    /// Artifact names in the manifest.
    pub fn names(&self) -> Vec<String> {
        self.manifest
            .get("artifacts")
            .and_then(|a| a.as_object())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Number of model variables recorded at AOT time.
    pub fn n_vars(&self) -> usize {
        self.manifest
            .get("n_vars")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as usize
    }

    /// Argument shapes for an artifact, as recorded at lowering time.
    pub fn arg_shapes(&self, name: &str) -> Result<Vec<Vec<usize>>> {
        let art = self
            .manifest
            .get("artifacts")
            .and_then(|a| a.get(name))
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        let args = art
            .get("args")
            .and_then(|a| a.as_array())
            .ok_or_else(|| anyhow!("artifact {name:?} missing args"))?;
        args.iter()
            .map(|arg| {
                arg.get("shape")
                    .and_then(|s| s.as_array())
                    .map(|dims| {
                        dims.iter()
                            .map(|d| d.as_f64().unwrap_or(0.0) as usize)
                            .collect()
                    })
                    .ok_or_else(|| anyhow!("artifact {name:?} bad shape"))
            })
            .collect()
    }

    /// Path of the HLO text file for an artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

/// A PJRT CPU client plus compiled-kernel cache.
pub struct XlaExecutor {
    client: xla::PjRtClient,
}

impl XlaExecutor {
    /// Create a CPU PJRT client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, store: &ArtifactStore, name: &str) -> Result<LoadedKernel> {
        let path = store.hlo_path(name);
        if !path.exists() {
            bail!("missing artifact file {}", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        Ok(LoadedKernel {
            name: name.to_string(),
            arg_shapes: store.arg_shapes(name)?,
            exe,
        })
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("uploading buffer: {e}"))
    }
}

/// One compiled executable with its expected argument shapes.
pub struct LoadedKernel {
    /// Artifact name.
    pub name: String,
    /// Expected argument shapes (from the manifest).
    pub arg_shapes: Vec<Vec<usize>>,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedKernel {
    /// Execute with device-resident buffers; returns the first element of
    /// the output tuple as a host literal (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::Literal> {
        if args.len() != self.arg_shapes.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.arg_shapes.len(),
                args.len()
            );
        }
        let out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} output: {e}", self.name))?;
        lit.to_tuple1()
            .map_err(|e| anyhow!("untupling {} output: {e}", self.name))
    }

    /// Execute and fetch the result as an f32 vector.
    pub fn run_f32(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        self.run_buffers(args)?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("converting {} output: {e}", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_loads() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.n_vars(), 400);
        let names = store.names();
        assert!(names.iter().any(|n| n == "potts_cond_energies"), "{names:?}");
        let shapes = store.arg_shapes("potts_cond_energies").unwrap();
        assert_eq!(shapes[0], vec![400, 400]);
        assert_eq!(shapes[1], vec![400, 10]);
        assert_eq!(shapes[2], Vec::<usize>::new());
    }

    #[test]
    fn load_and_execute_total_energy() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let store = ArtifactStore::open(&dir).unwrap();
        let exec = XlaExecutor::new().unwrap();
        let kernel = exec.load(&store, "potts_total_energy").unwrap();

        // Two agreeing variables with weight 1: ζ = β·1·δ = 2.0 at β=2.
        let n = 400;
        let mut w = vec![0.0f32; n * n];
        w[1] = 1.0; // w[0][1]
        w[n] = 1.0; // w[1][0]
        let mut x = vec![0.0f32; n * 10];
        for i in 0..n {
            x[i * 10] = 1.0; // everyone at value 0
        }
        let wb = exec.upload(&w, &[n, n]).unwrap();
        let xb = exec.upload(&x, &[n, 10]).unwrap();
        let beta = exec.upload(&[2.0f32], &[]).unwrap();
        let out = kernel.run_f32(&[&wb, &xb, &beta]).unwrap();
        assert_eq!(out.len(), 1);
        // ζ = 0.5 · β · Σ_ij W_ij δ = 0.5 · 2 · 2 = 2
        assert!((out[0] - 2.0).abs() < 1e-4, "got {}", out[0]);
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let store = ArtifactStore::open(&dir).unwrap();
        let exec = XlaExecutor::new().unwrap();
        let kernel = exec.load(&store, "potts_total_energy").unwrap();
        let b = exec.upload(&[0.0f32], &[]).unwrap();
        assert!(kernel.run_f32(&[&b]).is_err());
    }
}
