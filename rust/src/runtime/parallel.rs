//! Within-chain parallel execution: the chromatic sweep engine.
//!
//! Built on the site-addressable sampler surface
//! ([`Sampler::update_site`]): a [`crate::graph::Coloring`] partitions
//! the variables into classes that share no factor, so every site in a
//! class has a full conditional that is independent of the others given
//! the rest of the state — the classic chromatic Gibbs argument. The
//! engine sweeps the classes in order; within a class the sites are
//! split statically over a scoped `std::thread` worker pool.
//!
//! # Determinism contract
//!
//! Results are identical for ANY worker count ≥ 1 (bit-exact states for
//! deterministic-update samplers like plain Gibbs) because randomness is
//! keyed to *sites*, not workers: site `i` draws from its own `Pcg64`
//! stream, split once from the chain stream as `chain_rng.split(i)`.
//! Within a class the updates commute (conditional independence), so the
//! worker→site assignment only affects execution order, never values.
//! Checkpoints persist every per-site stream position, so `--resume`
//! replays the uninterrupted run bit-exactly too.
//!
//! # Protocol
//!
//! Workers never share mutable state: each owns a private copy of the
//! chain state. Per color class, a worker (1) updates its share of the
//! class against its private state, logging `(site, value)` pairs into
//! its publish buffer, (2) waits on a barrier, (3) applies everyone
//! else's published pairs to its private copy, (4) waits again so no one
//! reuses a buffer that is still being read. The coordinator (the chain
//! thread) participates in the same barriers, maintains the canonical
//! state, and runs per-sweep bookkeeping (sinks, progress, checkpoints)
//! while the workers idle at the round barrier.
//!
//! # Iteration accounting
//!
//! One "iteration" remains one site update, exactly as in the serial
//! random-scan path, so `iters`, `sampler_steps_total` and factor-eval
//! counters mean the same thing in both modes. A full sweep performs
//! `n` updates (one per site, in class order); if the remaining budget
//! is smaller than `n`, the final partial sweep stops mid-schedule.

use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::bench::workload::SamplerSpec;
use crate::graph::FactorGraph;
use crate::metrics::{labeled, Counter, Gauge, LatencyHistogram, MetricsHub, SamplerMetrics};
use crate::rng::Pcg64;
use crate::samplers::Hyperparams;

/// Everything the coordinator callback may inspect at a sweep boundary.
/// Workers are parked at the round barrier for the lifetime of this
/// value, so reading the per-site streams here is race-free.
pub struct SweepCtx<'a> {
    /// Site updates completed so far, counted from iteration 0 of the
    /// logical run (i.e. including pre-resume iterations).
    pub iter: u64,
    /// Canonical state after this sweep.
    pub state: &'a [u16],
    site_rngs: &'a [Mutex<Pcg64>],
}

impl SweepCtx<'_> {
    /// The `(state, inc)` position of every per-site stream — what a
    /// checkpoint must persist for a bit-exact parallel resume.
    pub fn site_rng_parts(&self) -> Vec<(u128, u128)> {
        self.site_rngs
            .iter()
            .map(|m| m.lock().unwrap().state_parts())
            .collect()
    }
}

/// The within-chain parallel executor for one chain.
pub struct ChromaticSweepEngine<'g> {
    graph: &'g FactorGraph,
    spec: SamplerSpec,
    workers: usize,
    hyperparams: Hyperparams,
    site_rngs: Vec<Mutex<Pcg64>>,
    metrics: Arc<SamplerMetrics>,
    sweeps: Arc<Counter>,
    barrier_lat: Arc<LatencyHistogram>,
    worker_busy: Vec<Arc<Gauge>>,
}

impl<'g> ChromaticSweepEngine<'g> {
    /// Build an engine for `workers` threads, deriving one RNG stream
    /// per site from the chain stream. Registers the `parallel_*`
    /// metrics on `hub` labeled with `chain`.
    pub fn new(
        graph: &'g FactorGraph,
        spec: SamplerSpec,
        workers: usize,
        chain_rng: &mut Pcg64,
        metrics: Arc<SamplerMetrics>,
        hub: &MetricsHub,
        chain: &str,
    ) -> Self {
        assert!(workers >= 1, "parallel engine needs at least one worker");
        assert!(
            spec.supports_parallel(),
            "sampler {spec:?} is not site-local; cannot run chromatically"
        );
        let site_rngs = (0..graph.n())
            .map(|i| Mutex::new(chain_rng.split(i as u64)))
            .collect();
        let worker_busy = (0..workers)
            .map(|w| {
                hub.gauge(&labeled(
                    "parallel_worker_busy_ratio",
                    &[("chain", chain), ("worker", &w.to_string())],
                ))
            })
            .collect();
        Self {
            graph,
            spec,
            workers,
            hyperparams: Hyperparams::default(),
            site_rngs,
            metrics,
            sweeps: hub.counter(&labeled("parallel_sweeps_total", &[("chain", chain)])),
            barrier_lat: hub.latency(&labeled("parallel_color_barrier_ns", &[("chain", chain)])),
            worker_busy,
        }
    }

    /// Reapply checkpointed hyperparameters to every worker's sampler
    /// (a resumed run may carry controller-tuned values from before).
    pub fn set_hyperparams(&mut self, h: Hyperparams) {
        self.hyperparams = h;
    }

    /// Restore the per-site stream positions saved by a checkpoint.
    pub fn restore_site_rngs(&mut self, parts: &[(u128, u128)]) -> Result<()> {
        if parts.len() != self.site_rngs.len() {
            bail!(
                "checkpoint has {} site streams, graph has {} variables",
                parts.len(),
                self.site_rngs.len()
            );
        }
        for (slot, &(s, inc)) in self.site_rngs.iter_mut().zip(parts) {
            *slot.get_mut().unwrap() = Pcg64::from_state_parts(s, inc);
        }
        Ok(())
    }

    /// Current per-site stream positions (for a final checkpoint written
    /// outside [`ChromaticSweepEngine::run`]).
    pub fn site_rng_parts(&self) -> Vec<(u128, u128)> {
        self.site_rngs
            .iter()
            .map(|m| m.lock().unwrap().state_parts())
            .collect()
    }

    /// Execute site updates `start_iter..end_iter` as chromatic sweeps,
    /// mutating `state` in place. `on_sweep` runs on the chain thread at
    /// every sweep boundary (workers parked), in ascending `iter` order.
    pub fn run(
        &self,
        state: &mut [u16],
        start_iter: u64,
        end_iter: u64,
        on_sweep: &mut dyn FnMut(SweepCtx<'_>),
    ) {
        let n = self.graph.n() as u64;
        assert_eq!(state.len() as u64, n, "state length mismatch");
        let total = end_iter.saturating_sub(start_iter);
        if total == 0 {
            return;
        }
        let classes = self.graph.coloring().classes();
        let w = self.workers;
        let full_sweeps = total / n;
        let tail = total % n;
        let rounds = full_sweeps + u64::from(tail > 0);

        // One reusable barrier; all parties traverse the identical
        // sequence of waits, so phases can never interleave.
        let barrier = Barrier::new(w + 1);
        let published: Vec<Mutex<Vec<(u32, u16)>>> =
            (0..w).map(|_| Mutex::new(Vec::new())).collect();

        std::thread::scope(|scope| {
            for wid in 0..w {
                let barrier = &barrier;
                let published = &published;
                let site_rngs = &self.site_rngs[..];
                let graph = self.graph;
                let sspec = self.spec;
                let hp = self.hyperparams;
                let metrics = self.metrics.clone();
                let busy_gauge = self.worker_busy[wid].clone();
                let init: Vec<u16> = state.to_vec();
                scope.spawn(move || {
                    worker_loop(WorkerArgs {
                        wid,
                        workers: w,
                        graph,
                        spec: sspec,
                        hyperparams: hp,
                        metrics,
                        state: init,
                        classes,
                        full_sweeps,
                        tail,
                        barrier,
                        published,
                        site_rngs,
                        busy_gauge,
                    })
                });
            }

            // Coordinator: mirrors the workers' barrier schedule and
            // keeps the canonical state.
            let mut done = 0u64;
            for round in 0..rounds {
                let budget = if round < full_sweeps { n } else { tail };
                let mut left = budget;
                for cls in classes {
                    if left == 0 {
                        break;
                    }
                    let take = (cls.len() as u64).min(left);
                    left -= take;
                    let t0 = Instant::now();
                    barrier.wait(); // all workers published
                    for buf in published.iter() {
                        for &(site, val) in buf.lock().unwrap().iter() {
                            state[site as usize] = val;
                        }
                    }
                    barrier.wait(); // everyone applied; buffers reusable
                    self.barrier_lat.record(t0.elapsed());
                }
                done += budget;
                self.sweeps.add(1);
                on_sweep(SweepCtx {
                    iter: start_iter + done,
                    state,
                    site_rngs: &self.site_rngs,
                });
                barrier.wait(); // release workers into the next round
            }
        });
    }
}

struct WorkerArgs<'a, 'g> {
    wid: usize,
    workers: usize,
    graph: &'g FactorGraph,
    spec: SamplerSpec,
    hyperparams: Hyperparams,
    metrics: Arc<SamplerMetrics>,
    state: Vec<u16>,
    classes: &'a [Vec<u32>],
    full_sweeps: u64,
    tail: u64,
    barrier: &'a Barrier,
    published: &'a [Mutex<Vec<(u32, u16)>>],
    site_rngs: &'a [Mutex<Pcg64>],
    busy_gauge: Arc<Gauge>,
}

fn worker_loop(args: WorkerArgs<'_, '_>) {
    let WorkerArgs {
        wid,
        workers,
        graph,
        spec,
        hyperparams,
        metrics,
        mut state,
        classes,
        full_sweeps,
        tail,
        barrier,
        published,
        site_rngs,
        busy_gauge,
    } = args;
    let n = graph.n() as u64;
    let mut sampler = spec.build(graph);
    if !hyperparams.is_empty() {
        sampler.set_hyperparams(&hyperparams);
    }
    sampler.attach_metrics(metrics);
    let rounds = full_sweeps + u64::from(tail > 0);
    let mut mine: Vec<(u32, u16)> = Vec::new();
    let started = Instant::now();
    let mut busy = std::time::Duration::ZERO;
    for round in 0..rounds {
        let budget = if round < full_sweeps { n } else { tail };
        let mut left = budget;
        for cls in classes {
            if left == 0 {
                break;
            }
            let take = (cls.len() as u64).min(left) as usize;
            left -= take as u64;
            // Static contiguous split of the class prefix over workers;
            // values don't depend on the split (see module docs).
            let chunk = take.div_ceil(workers);
            let lo = (wid * chunk).min(take);
            let hi = (lo + chunk).min(take);
            let t0 = Instant::now();
            mine.clear();
            for &site in &cls[lo..hi] {
                let site = site as usize;
                let mut rng = site_rngs[site].lock().unwrap();
                sampler.update_site(site, &mut state, &mut *rng);
                mine.push((site as u32, state[site]));
            }
            {
                let mut buf = published[wid].lock().unwrap();
                buf.clear();
                buf.extend_from_slice(&mine);
            }
            busy += t0.elapsed();
            barrier.wait(); // all published
            for (other, buf) in published.iter().enumerate() {
                if other == wid {
                    continue;
                }
                for &(site, val) in buf.lock().unwrap().iter() {
                    state[site as usize] = val;
                }
            }
            barrier.wait(); // safe to reuse buffers
        }
        barrier.wait(); // coordinator bookkeeping window
    }
    let wall = started.elapsed().as_secs_f64();
    if wall > 0.0 {
        busy_gauge.set(busy.as_secs_f64() / wall);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::metrics::MetricsHub;
    use crate::samplers::EnergyPath;

    fn run_engine(workers: usize, iters: u64, seed: u64) -> (Vec<u16>, u64) {
        let g = models::ising_multipartite(3, 6, 1.5);
        let hub = MetricsHub::new();
        let m = SamplerMetrics::register(&hub, &[("chain", "0")]);
        let mut rng = Pcg64::seeded(seed);
        let engine = {
            let mut e = ChromaticSweepEngine::new(
                &g,
                SamplerSpec::Gibbs(EnergyPath::Specialized),
                workers,
                &mut rng,
                m.clone(),
                &hub,
                "0",
            );
            e.set_hyperparams(Hyperparams::default());
            e
        };
        let mut state = vec![0u16; g.n()];
        let mut sweeps_seen = 0u64;
        engine.run(&mut state, 0, iters, &mut |ctx| {
            sweeps_seen += 1;
            assert!(ctx.iter <= iters);
            assert_eq!(ctx.site_rng_parts().len(), g.n());
        });
        assert_eq!(m.steps.get(), iters, "every site update must be counted");
        (state, sweeps_seen)
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let (s1, _) = run_engine(1, 18 * 10, 9);
        let (s4, _) = run_engine(4, 18 * 10, 9);
        assert_eq!(s1, s4, "chromatic sweeps must be worker-count invariant");
    }

    #[test]
    fn partial_final_sweep_counts_exactly() {
        // 18 sites, 40 iters = 2 full sweeps + a 4-site tail.
        let (_, sweeps) = run_engine(2, 40, 5);
        assert_eq!(sweeps, 3);
    }

    #[test]
    fn resume_from_site_streams_is_bit_exact() {
        let g = models::ising_multipartite(3, 4, 1.0);
        let n = g.n() as u64;
        let hub = MetricsHub::new();
        let m = SamplerMetrics::register(&hub, &[("chain", "0")]);

        let build = |rng: &mut Pcg64| {
            ChromaticSweepEngine::new(
                &g,
                SamplerSpec::Gibbs(EnergyPath::Specialized),
                2,
                rng,
                m.clone(),
                &hub,
                "0",
            )
        };

        // Uninterrupted: 6 sweeps.
        let mut rng = Pcg64::seeded(77);
        let engine = build(&mut rng);
        let mut full = vec![0u16; g.n()];
        engine.run(&mut full, 0, 6 * n, &mut |_| {});

        // Interrupted at sweep 3, then resumed from saved streams.
        let mut rng = Pcg64::seeded(77);
        let engine = build(&mut rng);
        let mut state = vec![0u16; g.n()];
        let mut saved: Option<(Vec<u16>, Vec<(u128, u128)>)> = None;
        engine.run(&mut state, 0, 3 * n, &mut |ctx| {
            if ctx.iter == 3 * n {
                saved = Some((ctx.state.to_vec(), ctx.site_rng_parts()));
            }
        });
        let (mut state, parts) = saved.expect("no checkpoint captured");
        let mut rng = Pcg64::seeded(123); // deliberately different chain stream
        let mut engine = build(&mut rng);
        engine.restore_site_rngs(&parts).unwrap();
        engine.run(&mut state, 3 * n, 6 * n, &mut |_| {});

        assert_eq!(full, state, "site-stream resume must replay bit-exactly");
    }

    #[test]
    fn rejects_wrong_stream_count() {
        let g = models::ising_multipartite(2, 3, 1.0);
        let hub = MetricsHub::new();
        let m = SamplerMetrics::register(&hub, &[("chain", "0")]);
        let mut rng = Pcg64::seeded(1);
        let mut e = ChromaticSweepEngine::new(
            &g,
            SamplerSpec::Gibbs(EnergyPath::Generic),
            1,
            &mut rng,
            m,
            &hub,
            "0",
        );
        assert!(e.restore_site_rngs(&[(1, 1)]).is_err());
    }

    #[test]
    fn parallel_metrics_flow_into_hub() {
        let g = models::ising_multipartite(3, 6, 1.5);
        let hub = MetricsHub::new();
        let m = SamplerMetrics::register(&hub, &[("chain", "0")]);
        let mut rng = Pcg64::seeded(4);
        let engine = ChromaticSweepEngine::new(
            &g,
            SamplerSpec::Gibbs(EnergyPath::Specialized),
            2,
            &mut rng,
            m,
            &hub,
            "0",
        );
        let mut state = vec![0u16; g.n()];
        engine.run(&mut state, 0, 5 * g.n() as u64, &mut |_| {});
        let snap = hub.snapshot();
        assert_eq!(
            snap.counter("parallel_sweeps_total{chain=\"0\"}"),
            Some(5)
        );
        let lat = snap
            .histogram("parallel_color_barrier_ns{chain=\"0\"}")
            .expect("barrier latency histogram missing");
        // 3 color classes × 5 sweeps = 15 barrier phases.
        assert_eq!(lat.count, 15);
        for w in 0..2 {
            let util = snap
                .gauge(&format!(
                    "parallel_worker_busy_ratio{{chain=\"0\",worker=\"{w}\"}}"
                ))
                .expect("missing utilization gauge");
            assert!((0.0..=1.0).contains(&util));
        }
    }
}
