//! Dense-model energy backend over the AOT kernels.
//!
//! [`XlaDenseBackend`] serves conditional-energy and total-energy queries
//! for the paper's dense RBF models by executing the Pallas/JAX artifacts
//! on the PJRT client. The interaction matrix is uploaded to the device
//! once at construction; per query only the one-hot state (n×D f32) moves.
//!
//! The invariant that makes this backend interchangeable with the native
//! factor-graph path — identical conditional energies to float32
//! tolerance — is enforced by [`parity_report`] and the integration tests.

use anyhow::{bail, Result};

use crate::graph::models::DenseModel;

use super::executor::{ArtifactStore, LoadedKernel, XlaExecutor};

/// Energy queries served by the compiled XLA kernels.
pub struct XlaDenseBackend {
    exec: XlaExecutor,
    cond_all: LoadedKernel,
    total: LoadedKernel,
    w_buf: xla::PjRtBuffer,
    beta_buf: xla::PjRtBuffer,
    n: usize,
    d: usize,
}

/// Which compiled lowering the backend executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// The Pallas kernels (interpret-mode HLO while-loop on CPU-PJRT;
    /// the Mosaic fast path on a real TPU). Validation target.
    Pallas,
    /// The fused-XLA-dot lowering of the same math — the CPU production
    /// path (see EXPERIMENTS.md §Perf for the measured gap).
    Dot,
}

impl XlaDenseBackend {
    /// Build with the CPU-appropriate default variant ([`KernelVariant::Dot`]).
    pub fn new(store: &ArtifactStore, model: &DenseModel) -> Result<Self> {
        Self::with_variant(store, model, KernelVariant::Dot)
    }

    /// Build executing the Pallas lowerings (validation / TPU parity).
    pub fn new_pallas(store: &ArtifactStore, model: &DenseModel) -> Result<Self> {
        Self::with_variant(store, model, KernelVariant::Pallas)
    }

    /// Build for a dense model; `store` must contain the artifacts for the
    /// model's domain size (D = 10 → potts_*, D = 2 → ising_*).
    pub fn with_variant(
        store: &ArtifactStore,
        model: &DenseModel,
        variant: KernelVariant,
    ) -> Result<Self> {
        let n = model.graph.n();
        let d = model.graph.domain_size() as usize;
        if n != store.n_vars() {
            bail!(
                "model has n = {n} but artifacts were lowered for n = {} — \
                 re-run `make artifacts` with matching GRID_N",
                store.n_vars()
            );
        }
        let (cond_name, total_name) = match (d, variant) {
            (2, KernelVariant::Pallas) => ("ising_cond_energies", "ising_total_energy"),
            (10, KernelVariant::Pallas) => ("potts_cond_energies", "potts_total_energy"),
            (2, KernelVariant::Dot) => ("ising_cond_energies_dot", "ising_total_energy_dot"),
            (10, KernelVariant::Dot) => ("potts_cond_energies_dot", "potts_total_energy_dot"),
            (other, _) => bail!("no artifacts lowered for D = {other}"),
        };
        let exec = XlaExecutor::new()?;
        let cond_all = exec.load(store, cond_name)?;
        let total = exec.load(store, total_name)?;
        let w_f32: Vec<f32> = model.kernel_weights.iter().map(|&v| v as f32).collect();
        let w_buf = exec.upload(&w_f32, &[n, n])?;
        let beta_buf = exec.upload(&[model.beta as f32], &[])?;
        Ok(Self {
            exec,
            cond_all,
            total,
            w_buf,
            beta_buf,
            n,
            d,
        })
    }

    /// Variables n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Domain size D.
    pub fn d(&self) -> usize {
        self.d
    }

    /// One-hot encode a state (row-major n×D f32).
    pub fn one_hot(&self, state: &[u16]) -> Vec<f32> {
        debug_assert_eq!(state.len(), self.n);
        let mut x = vec![0.0f32; self.n * self.d];
        for (i, &v) in state.iter().enumerate() {
            x[i * self.d + v as usize] = 1.0;
        }
        x
    }

    /// Conditional energies for ALL variables and values: returns the
    /// row-major n×D table ε_u(i) computed by the Pallas matmul kernel.
    pub fn cond_energies_all(&self, state: &[u16]) -> Result<Vec<f32>> {
        let x = self.one_hot(state);
        let xb = self.exec.upload(&x, &[self.n, self.d])?;
        self.cond_all.run_f32(&[&self.w_buf, &xb, &self.beta_buf])
    }

    /// Total energy ζ(x) via the compiled kernel.
    pub fn total_energy(&self, state: &[u16]) -> Result<f64> {
        let x = self.one_hot(state);
        let xb = self.exec.upload(&x, &[self.n, self.d])?;
        let out = self.total.run_f32(&[&self.w_buf, &xb, &self.beta_buf])?;
        Ok(out[0] as f64)
    }
}

/// Compare XLA and native energies on random states; returns the max
/// |xla − native| over conditional-energy tables and total energies.
/// This is the L1/L2↔L3 integration check run by `mbgibbs check-artifacts`.
pub fn parity_report(
    backend: &XlaDenseBackend,
    model: &DenseModel,
    states: usize,
    seed: u64,
) -> Result<f64> {
    use crate::rng::{Pcg64, Rng};
    let g = &model.graph;
    let n = g.n();
    let d = g.domain_size() as usize;
    let mut rng = Pcg64::seeded(seed);
    let mut worst = 0.0f64;
    let mut native = vec![0.0f64; d];
    for _ in 0..states {
        let mut state: Vec<u16> = (0..n).map(|_| rng.index(d) as u16).collect();
        let table = backend.cond_energies_all(&state)?;
        for i in 0..n {
            g.cond_energies_fast(&mut state, i, &mut native);
            for u in 0..d {
                let diff = (table[i * d + u] as f64 - native[u]).abs();
                worst = worst.max(diff);
            }
        }
        let zx = backend.total_energy(&state)?;
        let zn = g.total_energy(&state);
        // total energies are O(10³); compare with relative tolerance
        worst = worst.max((zx - zn).abs() / zn.abs().max(1.0));
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use std::path::PathBuf;

    fn store() -> Option<ArtifactStore> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| {
            ArtifactStore::open(&dir).expect("manifest parse")
        })
    }

    #[test]
    fn potts_parity_with_native() {
        let Some(store) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let model = models::paper_potts();
        let backend = XlaDenseBackend::new(&store, &model).unwrap();
        let worst = parity_report(&backend, &model, 2, 7).unwrap();
        assert!(worst < 2e-3, "XLA vs native deviation {worst}");
    }

    /// The Pallas and fused-dot lowerings of the same math must agree to
    /// f32 tolerance — the L1-kernel-vs-XLA-dot equivalence, checked
    /// through the full artifact + PJRT path.
    #[test]
    fn pallas_and_dot_variants_agree() {
        let Some(store) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let model = models::paper_potts();
        let pallas = XlaDenseBackend::new_pallas(&store, &model).unwrap();
        let dot = XlaDenseBackend::new(&store, &model).unwrap();
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seeded(21);
        let state: Vec<u16> = (0..400).map(|_| rng.index(10) as u16).collect();
        let a = pallas.cond_energies_all(&state).unwrap();
        let b = dot.cond_energies_all(&state).unwrap();
        let worst = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(worst < 1e-3, "pallas vs dot deviation {worst}");
        let za = pallas.total_energy(&state).unwrap();
        let zb = dot.total_energy(&state).unwrap();
        assert!((za - zb).abs() / zb.abs().max(1.0) < 1e-5, "{za} vs {zb}");
    }

    #[test]
    fn ising_parity_with_native() {
        let Some(store) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let model = models::paper_ising();
        let backend = XlaDenseBackend::new(&store, &model).unwrap();
        let worst = parity_report(&backend, &model, 2, 8).unwrap();
        assert!(worst < 2e-3, "XLA vs native deviation {worst}");
    }

    #[test]
    fn rejects_mismatched_model() {
        let Some(store) = store() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let model = models::potts_rbf(3, 10, 1.0, 1.5); // n = 9 != 400
        assert!(XlaDenseBackend::new(&store, &model).is_err());
    }
}
