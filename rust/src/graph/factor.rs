//! Factor kinds.
//!
//! All factors are non-negative (the paper's WLOG convention): π(x) ∝
//! exp(Σ φ(x)) with 0 ≤ φ(x) ≤ M_φ. Three kinds cover the paper's
//! experiments and general usage:
//!
//! * [`Factor::PottsPair`] — `w · δ(x_i, x_j)`: the §B Potts interaction.
//! * [`Factor::IsingPair`] — `w · (s_i s_j + 1)` with spins s = ±1 encoded
//!   as values {0, 1}: the §B Ising interaction (equals `2w · δ`).
//! * [`Factor::Table`] — arbitrary non-negative table over ≤ 4 variables:
//!   the general factor-graph case (and the O(D·arity) cost model).

/// One non-negative factor φ.
#[derive(Clone, Debug)]
pub enum Factor {
    /// `w * delta(x_i, x_j)`, w ≥ 0.
    PottsPair { i: u32, j: u32, w: f64 },
    /// `w * (s_i * s_j + 1)` with s = 2x − 1 ∈ {−1, +1}, w ≥ 0.
    IsingPair { i: u32, j: u32, w: f64 },
    /// Dense non-negative table over `vars` (row-major, last var fastest).
    Table {
        vars: Vec<u32>,
        /// Domain size used to index the table.
        d: u16,
        table: Vec<f64>,
    },
}

impl Factor {
    /// φ(x).
    #[inline]
    pub fn value(&self, state: &[u16]) -> f64 {
        match self {
            Factor::PottsPair { i, j, w } => {
                if state[*i as usize] == state[*j as usize] {
                    *w
                } else {
                    0.0
                }
            }
            Factor::IsingPair { i, j, w } => {
                // s_i s_j + 1 = 2 if equal else 0
                if state[*i as usize] == state[*j as usize] {
                    2.0 * *w
                } else {
                    0.0
                }
            }
            Factor::Table { vars, d, table } => {
                let mut idx = 0usize;
                for &v in vars {
                    idx = idx * (*d as usize) + state[v as usize] as usize;
                }
                table[idx]
            }
        }
    }

    /// M_φ = max_x φ(x) (Definition 1).
    pub fn max_energy(&self) -> f64 {
        match self {
            Factor::PottsPair { w, .. } => *w,
            Factor::IsingPair { w, .. } => 2.0 * *w,
            Factor::Table { table, .. } => {
                table.iter().cloned().fold(0.0f64, f64::max)
            }
        }
    }

    /// Visit each variable this factor depends on.
    #[inline]
    pub fn for_each_var<F: FnMut(usize)>(&self, mut f: F) {
        match self {
            Factor::PottsPair { i, j, .. } | Factor::IsingPair { i, j, .. } => {
                f(*i as usize);
                f(*j as usize);
            }
            Factor::Table { vars, .. } => {
                for &v in vars {
                    f(v as usize);
                }
            }
        }
    }

    /// Number of variables (arity).
    pub fn arity(&self) -> usize {
        match self {
            Factor::PottsPair { .. } | Factor::IsingPair { .. } => 2,
            Factor::Table { vars, .. } => vars.len(),
        }
    }

    /// Add this factor's contribution to the conditional-energy vector of
    /// variable `i`: `out[u] += φ(x_{i→u})` for all u — in O(1) for
    /// pairwise factors, O(D) for tables. `state[i]` may hold any value;
    /// it is not read for pairwise factors and is overwritten per-u for
    /// tables (callers restore it afterwards).
    #[inline]
    pub fn accumulate_cond(&self, state: &mut [u16], i: usize, out: &mut [f64]) {
        match self {
            Factor::PottsPair { i: a, j: b, w } => {
                let other = if *a as usize == i { *b } else { *a } as usize;
                out[state[other] as usize] += *w;
            }
            Factor::IsingPair { i: a, j: b, w } => {
                let other = if *a as usize == i { *b } else { *a } as usize;
                out[state[other] as usize] += 2.0 * *w;
            }
            Factor::Table { .. } => {
                for (u, slot) in out.iter_mut().enumerate() {
                    state[i] = u as u16;
                    *slot += self.value(state);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn potts_pair_value() {
        let f = Factor::PottsPair { i: 0, j: 1, w: 2.5 };
        assert_eq!(f.value(&[3, 3]), 2.5);
        assert_eq!(f.value(&[3, 4]), 0.0);
        assert_eq!(f.max_energy(), 2.5);
        assert_eq!(f.arity(), 2);
    }

    #[test]
    fn ising_pair_value() {
        let f = Factor::IsingPair { i: 0, j: 1, w: 0.7 };
        // equal spins: s_i s_j + 1 = 2
        assert!((f.value(&[0, 0]) - 1.4).abs() < 1e-15);
        assert!((f.value(&[1, 1]) - 1.4).abs() < 1e-15);
        assert_eq!(f.value(&[0, 1]), 0.0);
        assert!((f.max_energy() - 1.4).abs() < 1e-15);
    }

    #[test]
    fn table_value_row_major() {
        // f(x0, x1) over D=3: table[x0*3 + x1]
        let table: Vec<f64> = (0..9).map(|v| v as f64).collect();
        let f = Factor::Table {
            vars: vec![0, 1],
            d: 3,
            table,
        };
        assert_eq!(f.value(&[0, 0]), 0.0);
        assert_eq!(f.value(&[1, 2]), 5.0);
        assert_eq!(f.value(&[2, 1]), 7.0);
        assert_eq!(f.max_energy(), 8.0);
    }

    #[test]
    fn unary_table() {
        let f = Factor::Table {
            vars: vec![2],
            d: 4,
            table: vec![0.1, 0.2, 0.3, 0.05],
        };
        assert_eq!(f.value(&[0, 0, 2]), 0.3);
        assert_eq!(f.max_energy(), 0.3);
        assert_eq!(f.arity(), 1);
    }

    #[test]
    fn accumulate_cond_matches_value_loop() {
        let factors = vec![
            Factor::PottsPair { i: 0, j: 1, w: 1.0 },
            Factor::IsingPair { i: 1, j: 0, w: 0.5 },
            Factor::Table {
                vars: vec![0, 1],
                d: 3,
                table: (0..9).map(|v| (v * v) as f64 * 0.1).collect(),
            },
        ];
        for f in &factors {
            let mut state = vec![2u16, 1u16];
            let mut fast = vec![0.0; 3];
            f.accumulate_cond(&mut state, 0, &mut fast);
            for u in 0..3u16 {
                let mut s = vec![u, 1u16];
                let want = f.value(&mut s);
                assert!(
                    (fast[u as usize] - want).abs() < 1e-12,
                    "{f:?} u={u}: {} vs {want}",
                    fast[u as usize]
                );
            }
        }
    }
}
