//! Definition-1 statistics: Δ, L, Ψ and friends, computed once per graph.

/// Cached graph statistics (Definition 1 of the paper).
#[derive(Clone, Debug, PartialEq)]
pub struct GraphStats {
    /// Maximum degree Δ = max_i |A[i]|.
    pub delta: usize,
    /// Total maximum energy Ψ = Σ_φ M_φ.
    pub psi: f64,
    /// Local maximum energy L = max_i Σ_{φ∈A[i]} M_φ.
    pub l: f64,
    /// Per-variable local energies L_i = Σ_{φ∈A[i]} M_φ.
    pub per_var_l: Vec<f64>,
}

/// Summary of a [`crate::graph::Coloring`]: how much chromatic
/// parallelism the factor structure permits. `num_colors == n` (complete
/// graphs) means none; few colors with large, balanced classes is the
/// favorable regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColoringStats {
    /// Number of color classes.
    pub num_colors: usize,
    /// Size of the largest class (the per-sweep parallelism ceiling).
    pub largest_class: usize,
    /// Size of the smallest class (where barrier overhead dominates).
    pub smallest_class: usize,
}

impl GraphStats {
    pub(crate) fn compute(
        n: usize,
        max_energies: &[f64],
        adj_offsets: &[u32],
        adj_factors: &[u32],
    ) -> Self {
        let psi: f64 = max_energies.iter().sum();
        let mut delta = 0usize;
        let mut l = 0.0f64;
        let mut per_var_l = vec![0.0f64; n];
        for i in 0..n {
            let lo = adj_offsets[i] as usize;
            let hi = adj_offsets[i + 1] as usize;
            delta = delta.max(hi - lo);
            let li: f64 = adj_factors[lo..hi]
                .iter()
                .map(|&fid| max_energies[fid as usize])
                .sum();
            per_var_l[i] = li;
            l = l.max(li);
        }
        Self {
            delta,
            psi,
            l,
            per_var_l,
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::FactorGraphBuilder;

    #[test]
    fn star_graph_stats() {
        // variable 0 is the hub of a 5-spoke star, each spoke weight 0.5
        let mut b = FactorGraphBuilder::new(6, 2);
        for j in 1..6 {
            b.add_potts_pair(0, j, 0.5);
        }
        let g = b.build();
        let s = g.stats();
        assert_eq!(s.delta, 5);
        assert!((s.psi - 2.5).abs() < 1e-12);
        assert!((s.l - 2.5).abs() < 1e-12); // the hub
        assert!((s.per_var_l[1] - 0.5).abs() < 1e-12); // a spoke
    }

    #[test]
    fn psi_can_be_small_with_many_factors() {
        // Many low-energy factors: Psi << |Phi| — the regime where
        // MIN-Gibbs wins (paper §1.1).
        let n = 50;
        let mut b = FactorGraphBuilder::new(n, 2);
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                b.add_potts_pair(i, j, 0.001);
            }
        }
        let g = b.build();
        let s = g.stats();
        let m = n * (n - 1) / 2;
        assert_eq!(g.num_factors(), m);
        assert!((s.psi - 0.001 * m as f64).abs() < 1e-9);
        assert!(s.psi < 2.0);
        assert_eq!(s.delta, n - 1);
    }

    #[test]
    fn l_uses_max_energy_not_value() {
        // Ising pair max energy is 2w.
        let mut b = FactorGraphBuilder::new(2, 2);
        b.add_ising_pair(0, 1, 1.5);
        let g = b.build();
        assert!((g.stats().psi - 3.0).abs() < 1e-12);
        assert!((g.stats().l - 3.0).abs() < 1e-12);
    }
}
