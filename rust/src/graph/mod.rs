//! Factor-graph substrate: the model class of the paper (§1.1).
//!
//! A [`FactorGraph`] holds `n` categorical variables over a shared domain
//! `{0, .., D-1}` and a set of non-negative factors φ with π(x) ∝
//! exp(Σ_φ φ(x)). The bipartite variable↔factor adjacency is stored in CSR
//! form; Definition-1 statistics (max energies M_φ, total Ψ, local L,
//! degree Δ) are computed at build time and cached.

pub mod builder;
pub mod coloring;
pub mod factor;
pub mod io;
pub mod models;
pub mod stats;

pub use builder::FactorGraphBuilder;
pub use coloring::Coloring;
pub use factor::Factor;
pub use stats::{ColoringStats, GraphStats};

use std::sync::OnceLock;

/// A variable assignment: `state[i] ∈ {0, .., D-1}`.
pub type State = Vec<u16>;

/// An immutable factor graph with cached Definition-1 statistics.
#[derive(Clone, Debug)]
pub struct FactorGraph {
    n: usize,
    d: u16,
    factors: Vec<Factor>,
    max_energies: Vec<f64>,
    // CSR: factors adjacent to variable i are
    // adj_factors[adj_offsets[i] .. adj_offsets[i+1]].
    adj_offsets: Vec<u32>,
    adj_factors: Vec<u32>,
    stats: GraphStats,
    // Lazily computed greedy coloring (chromatic parallel scheduling);
    // a clone carries the already-computed coloring along.
    coloring: OnceLock<Coloring>,
}

impl FactorGraph {
    pub(crate) fn from_parts(n: usize, d: u16, factors: Vec<Factor>) -> Self {
        assert!(n > 0 && d >= 2, "need n > 0 variables and D >= 2 values");
        let max_energies: Vec<f64> = factors.iter().map(|f| f.max_energy()).collect();
        for (fid, &m) in max_energies.iter().enumerate() {
            assert!(
                m.is_finite() && m >= 0.0,
                "factor {fid} has invalid max energy {m}"
            );
        }
        // Build CSR adjacency.
        let mut degree = vec![0u32; n];
        for f in &factors {
            f.for_each_var(|v| degree[v] += 1);
        }
        let mut adj_offsets = vec![0u32; n + 1];
        for i in 0..n {
            adj_offsets[i + 1] = adj_offsets[i] + degree[i];
        }
        let mut cursor: Vec<u32> = adj_offsets[..n].to_vec();
        let mut adj_factors = vec![0u32; adj_offsets[n] as usize];
        for (fid, f) in factors.iter().enumerate() {
            f.for_each_var(|v| {
                adj_factors[cursor[v] as usize] = fid as u32;
                cursor[v] += 1;
            });
        }
        let stats = GraphStats::compute(n, &max_energies, &adj_offsets, &adj_factors);
        Self {
            n,
            d,
            factors,
            max_energies,
            adj_offsets,
            adj_factors,
            stats,
            coloring: OnceLock::new(),
        }
    }

    /// Number of variables n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Shared domain size D.
    pub fn domain_size(&self) -> u16 {
        self.d
    }

    /// Number of factors |Φ|.
    pub fn num_factors(&self) -> usize {
        self.factors.len()
    }

    /// The factor with id `fid`.
    pub fn factor(&self, fid: usize) -> &Factor {
        &self.factors[fid]
    }

    /// All factors.
    pub fn factors(&self) -> &[Factor] {
        &self.factors
    }

    /// Factor ids adjacent to variable `i` (the paper's A[i]).
    #[inline]
    pub fn factors_of(&self, i: usize) -> &[u32] {
        let lo = self.adj_offsets[i] as usize;
        let hi = self.adj_offsets[i + 1] as usize;
        &self.adj_factors[lo..hi]
    }

    /// Maximum energy M_φ of factor `fid` (Definition 1).
    #[inline]
    pub fn max_energy(&self, fid: usize) -> f64 {
        self.max_energies[fid]
    }

    /// All per-factor maximum energies.
    pub fn max_energies(&self) -> &[f64] {
        &self.max_energies
    }

    /// Cached Definition-1 statistics (Δ, L, Ψ, per-variable L_i).
    pub fn stats(&self) -> &GraphStats {
        &self.stats
    }

    /// The greedy variable coloring (computed on first use, then cached).
    /// Same-color variables share no factor, so a whole color class can
    /// be resampled concurrently — see [`crate::runtime::parallel`].
    pub fn coloring(&self) -> &Coloring {
        self.coloring.get_or_init(|| Coloring::compute(self))
    }

    /// Evaluate factor `fid` on `state`.
    #[inline]
    pub fn value(&self, fid: usize, state: &[u16]) -> f64 {
        self.factors[fid].value(state)
    }

    /// ζ(x) = Σ_φ φ(x): the total energy.
    pub fn total_energy(&self, state: &[u16]) -> f64 {
        self.factors.iter().map(|f| f.value(state)).sum()
    }

    /// Σ_{φ ∈ A[i]} φ(x): the energy local to variable `i`.
    pub fn local_energy(&self, state: &[u16], i: usize) -> f64 {
        self.factors_of(i)
            .iter()
            .map(|&fid| self.factors[fid as usize].value(state))
            .sum()
    }

    /// Conditional energies ε_u = Σ_{φ∈A[i]} φ(x_{i→u}) for all u, via the
    /// generic per-factor evaluation loop — the O(DΔ) path of Algorithm 1
    /// that the paper's cost model assumes. `state` is restored on return.
    ///
    /// This is the *measured* baseline for the Table-1 reproduction; use
    /// [`FactorGraph::cond_energies_fast`] in production.
    pub fn cond_energies_generic(&self, state: &mut [u16], i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.d as usize);
        let saved = state[i];
        for (u, slot) in out.iter_mut().enumerate() {
            state[i] = u as u16;
            *slot = self.local_energy(state, i);
        }
        state[i] = saved;
    }

    /// Conditional energies via factor-structure-aware accumulation:
    /// pairwise factors contribute to a single `out[u]` bucket in O(1),
    /// so the whole call is O(Δ + D) instead of O(ΔD).
    pub fn cond_energies_fast(&self, state: &mut [u16], i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.d as usize);
        out.fill(0.0);
        let saved = state[i];
        for &fid in self.factors_of(i) {
            self.factors[fid as usize].accumulate_cond(state, i, out);
        }
        state[i] = saved;
    }

    /// Flat index of the first factor touching each variable — handy for
    /// deterministic iteration in tests.
    pub fn degree(&self, i: usize) -> usize {
        self.factors_of(i).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_var_graph() -> FactorGraph {
        // phi0 = 1.5 * delta(x0, x1); phi1 = table on x0: [0.2, 0.7]
        let mut b = FactorGraphBuilder::new(2, 2);
        b.add_potts_pair(0, 1, 1.5);
        b.add_table(vec![0], vec![0.2, 0.7]);
        b.build()
    }

    #[test]
    fn adjacency_csr() {
        let g = two_var_graph();
        assert_eq!(g.factors_of(0), &[0, 1]);
        assert_eq!(g.factors_of(1), &[0]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 1);
    }

    #[test]
    fn stats_definition_1() {
        let g = two_var_graph();
        let s = g.stats();
        assert_eq!(s.delta, 2);
        // Psi = 1.5 + 0.7; L = max(1.5 + 0.7, 1.5)
        assert!((s.psi - 2.2).abs() < 1e-12);
        assert!((s.l - 2.2).abs() < 1e-12);
    }

    #[test]
    fn energies() {
        let g = two_var_graph();
        assert!((g.total_energy(&[0, 0]) - (1.5 + 0.2)).abs() < 1e-12);
        assert!((g.total_energy(&[1, 0]) - 0.7).abs() < 1e-12);
        assert!((g.local_energy(&[0, 0], 1) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn cond_energies_generic_vs_fast() {
        let g = two_var_graph();
        let mut state = vec![1u16, 0u16];
        let mut a = vec![0.0; 2];
        let mut b = vec![0.0; 2];
        for i in 0..2 {
            g.cond_energies_generic(&mut state, i, &mut a);
            g.cond_energies_fast(&mut state, i, &mut b);
            for u in 0..2 {
                assert!((a[u] - b[u]).abs() < 1e-12, "i={i} u={u}: {a:?} vs {b:?}");
            }
        }
        assert_eq!(state, vec![1, 0]); // state restored
    }

    #[test]
    fn cond_energies_values() {
        let g = two_var_graph();
        let mut state = vec![0u16, 1u16];
        let mut e = vec![0.0; 2];
        g.cond_energies_fast(&mut state, 0, &mut e);
        // u=0: potts 0 (x1=1) + table 0.2; u=1: potts 1.5 + table 0.7
        assert!((e[0] - 0.2).abs() < 1e-12);
        assert!((e[1] - 2.2).abs() < 1e-12);
    }
}
