//! Graph I/O: the UAI competition file format.
//!
//! The UAI format describes a Markov network as a preamble (variable
//! cardinalities and factor scopes) followed by one dense potential
//! table per factor:
//!
//! ```text
//! MARKOV
//! 3                 # variables
//! 2 2 2             # cardinalities
//! 2                 # factors
//! 2 0 1             # scope: arity, then variable ids
//! 2 1 2
//!
//! 4                 # table size, then D^arity values (last var fastest)
//!  1.0 0.5 0.5 1.0
//! 4
//!  1.0 2.0 2.0 1.0
//! ```
//!
//! UAI potentials are *multiplicative* (π ∝ Π θ_φ); this crate's
//! [`FactorGraph`] wants non-negative *energies* with π ∝ exp(Σ φ). The
//! loader takes φ = ln θ and shifts each table by −min ln θ so entries
//! are non-negative — a per-factor constant that cancels in π. Zero
//! potentials (hard constraints) would need −∞ energies and are
//! rejected.
//!
//! Restrictions inherited from the substrate: every variable must share
//! one cardinality D (the paper's model class), and factor arity is
//! capped at 4 (the [`FactorGraphBuilder`] table limit).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{FactorGraph, FactorGraphBuilder};

/// Parse a UAI `MARKOV` document into a [`FactorGraph`].
pub fn parse_uai(text: &str) -> Result<FactorGraph> {
    // Strip `#`/`//`-to-end-of-line comments (not part of the official
    // grammar, but common in hand-written files), then tokenize.
    let cleaned: String = text
        .lines()
        .map(|l| {
            let l = l.split('#').next().unwrap_or("");
            l.split("//").next().unwrap_or("")
        })
        .collect::<Vec<_>>()
        .join("\n");
    let mut toks = cleaned.split_whitespace();
    let mut next = |what: &str| {
        toks.next()
            .ok_or_else(|| anyhow!("unexpected end of file while reading {what}"))
    };

    let header = next("header")?;
    if !header.eq_ignore_ascii_case("MARKOV") {
        bail!("unsupported UAI network type {header:?} (only MARKOV)");
    }
    let n: usize = next("variable count")?
        .parse()
        .context("bad variable count")?;
    if n == 0 {
        bail!("UAI file declares zero variables");
    }
    let mut cards = Vec::with_capacity(n);
    for i in 0..n {
        let c: u16 = next("cardinality")?
            .parse()
            .with_context(|| format!("bad cardinality for variable {i}"))?;
        cards.push(c);
    }
    let d = cards[0];
    if d < 2 {
        bail!("domain size must be >= 2, got {d}");
    }
    if cards.iter().any(|&c| c != d) {
        bail!(
            "variables must share one cardinality (found {cards:?}); the \
             factor-graph substrate uses a single domain D"
        );
    }
    let m: usize = next("factor count")?.parse().context("bad factor count")?;
    if m == 0 {
        bail!("UAI file declares zero factors");
    }
    let mut scopes: Vec<Vec<u32>> = Vec::with_capacity(m);
    for f in 0..m {
        let arity: usize = next("factor arity")?
            .parse()
            .with_context(|| format!("bad arity for factor {f}"))?;
        if arity == 0 || arity > 4 {
            bail!("factor {f} has arity {arity}; supported range is 1..=4");
        }
        let mut vars = Vec::with_capacity(arity);
        for _ in 0..arity {
            let v: u32 = next("scope variable")?
                .parse()
                .with_context(|| format!("bad scope variable in factor {f}"))?;
            if v as usize >= n {
                bail!("factor {f} references variable {v}, but n = {n}");
            }
            vars.push(v);
        }
        scopes.push(vars);
    }

    let mut b = FactorGraphBuilder::new(n, d);
    for (f, vars) in scopes.into_iter().enumerate() {
        let want = (d as usize).pow(vars.len() as u32);
        let len: usize = next("table size")?
            .parse()
            .with_context(|| format!("bad table size for factor {f}"))?;
        if len != want {
            bail!("factor {f} table size {len} != D^arity = {want}");
        }
        let mut energies = Vec::with_capacity(len);
        for t in 0..len {
            let v: f64 = next("table value")?
                .parse()
                .with_context(|| format!("bad table value {t} in factor {f}"))?;
            if !(v.is_finite() && v > 0.0) {
                bail!(
                    "factor {f} has potential {v}; UAI potentials must be finite and > 0 \
                     (zero potentials need -inf energies, which the substrate rejects)"
                );
            }
            energies.push(v.ln());
        }
        // Shift to non-negative energies; a per-factor constant cancels in π.
        let min = energies.iter().cloned().fold(f64::INFINITY, f64::min);
        for e in energies.iter_mut() {
            *e -= min;
        }
        b.add_table(vars, energies);
    }
    if toks.next().is_some() {
        bail!("trailing tokens after the last factor table");
    }
    Ok(b.build())
}

/// Render a [`FactorGraph`] as a UAI `MARKOV` document (potentials are
/// `exp` of the stored energies, so `parse_uai(write_uai(g))` defines the
/// same distribution π as `g`).
pub fn write_uai(g: &FactorGraph) -> String {
    let n = g.n();
    let d = g.domain_size() as usize;
    let mut out = String::new();
    out.push_str("MARKOV\n");
    out.push_str(&format!("{n}\n"));
    let cards: Vec<String> = (0..n).map(|_| d.to_string()).collect();
    out.push_str(&cards.join(" "));
    out.push('\n');
    out.push_str(&format!("{}\n", g.num_factors()));

    let mut scopes: Vec<Vec<u32>> = Vec::with_capacity(g.num_factors());
    for f in g.factors() {
        let mut vars = Vec::new();
        f.for_each_var(|v| vars.push(v as u32));
        out.push_str(&format!("{} ", vars.len()));
        let toks: Vec<String> = vars.iter().map(|v| v.to_string()).collect();
        out.push_str(&toks.join(" "));
        out.push('\n');
        scopes.push(vars);
    }
    out.push('\n');

    let mut scratch = vec![0u16; n];
    for (fid, vars) in scopes.iter().enumerate() {
        let len = d.pow(vars.len() as u32);
        out.push_str(&format!("{len}\n"));
        let mut vals = Vec::with_capacity(len);
        for idx in 0..len {
            // Decode idx over the scope, last variable fastest.
            let mut rem = idx;
            for &v in vars.iter().rev() {
                scratch[v as usize] = (rem % d) as u16;
                rem /= d;
            }
            vals.push(format!("{}", g.value(fid, &scratch).exp()));
        }
        out.push_str(&vals.join(" "));
        out.push('\n');
    }
    out
}

/// Load a UAI model from a file.
pub fn load_uai(path: &Path) -> Result<FactorGraph> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading UAI model {}", path.display()))?;
    parse_uai(&text).with_context(|| format!("in {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exact_distribution;

    /// A 3-variable chain with one unary and two pairwise potentials.
    const HAND_WRITTEN: &str = "\
MARKOV
3
2 2 2
3
1 0
2 0 1
2 1 2

2
 2.0 0.5
4
 1.0 0.25 0.25 1.0
4
 3.0 1.0 1.0 3.0
";

    #[test]
    fn parses_hand_written_file() {
        let g = parse_uai(HAND_WRITTEN).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.domain_size(), 2);
        assert_eq!(g.num_factors(), 3);
        assert_eq!(g.factors_of(1), &[1, 2]);
        // Factor 1 on (x0, x1): energies ln([1, .25, .25, 1]) shifted to
        // [ln 4, 0, 0, ln 4].
        let want = 4.0f64.ln();
        assert!((g.value(1, &[0, 0, 0]) - want).abs() < 1e-12);
        assert!(g.value(1, &[0, 1, 0]).abs() < 1e-12);
    }

    /// parse → write → parse defines the same distribution π (energies
    /// differ by per-factor constants, π does not).
    #[test]
    fn roundtrip_preserves_distribution() {
        let g1 = parse_uai(HAND_WRITTEN).unwrap();
        let text = write_uai(&g1);
        let g2 = parse_uai(&text).unwrap();
        assert_eq!(g1.n(), g2.n());
        assert_eq!(g1.num_factors(), g2.num_factors());
        let (p1, p2) = (exact_distribution(&g1), exact_distribution(&g2));
        for (a, b) in p1.iter().zip(p2.iter()) {
            assert!((a - b).abs() < 1e-12, "π diverged: {a} vs {b}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("mbgibbs_uai_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.uai");
        std::fs::write(&path, HAND_WRITTEN).unwrap();
        let g = load_uai(&path).unwrap();
        assert_eq!(g.n(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exported_builtin_model_roundtrips() {
        let g1 = crate::graph::models::tiny_random(4, 3, 0.8, 17);
        let g2 = parse_uai(&write_uai(&g1)).unwrap();
        let (p1, p2) = (exact_distribution(&g1), exact_distribution(&g2));
        for (a, b) in p1.iter().zip(p2.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn rejects_malformed_documents() {
        // wrong network type
        assert!(parse_uai("BAYES\n1\n2\n1\n1 0\n2\n1 1\n").is_err());
        // mixed cardinalities (substrate wants one shared D)
        assert!(parse_uai("MARKOV\n2\n2 3\n1\n2 0 1\n6\n1 1 1 1 1 1\n").is_err());
        // zero potential (hard constraint)
        assert!(parse_uai("MARKOV\n1\n2\n1\n1 0\n2\n1.0 0.0\n").is_err());
        // table size mismatch
        assert!(parse_uai("MARKOV\n2\n2 2\n1\n2 0 1\n3\n1 1 1\n").is_err());
        // scope out of range
        assert!(parse_uai("MARKOV\n2\n2 2\n1\n2 0 5\n4\n1 1 1 1\n").is_err());
        // truncated
        assert!(parse_uai("MARKOV\n2\n2 2\n1\n2 0 1\n4\n1 1\n").is_err());
        // trailing garbage
        assert!(parse_uai("MARKOV\n1\n2\n1\n1 0\n2\n1 2\n99\n").is_err());
    }
}
