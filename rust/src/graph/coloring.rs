//! Greedy variable coloring over the factor adjacency.
//!
//! Two variables are adjacent iff some factor touches both; variables of
//! the same color therefore share no factor, so their full conditionals
//! are independent given the rest of the state and a whole color class
//! can be resampled concurrently (chromatic Gibbs scheduling, cf. the
//! hierarchy-width line of work on which parallelism factor-graph
//! structure permits). The executor in [`crate::runtime::parallel`]
//! sweeps one class at a time.
//!
//! The coloring is the classic Welsh–Powell greedy: visit variables in
//! order of decreasing adjacency degree and give each the smallest color
//! unused among its neighbors. That uses at most Δ_adj + 1 colors and is
//! exact on the paper's complete-graph workloads (n colors — no
//! parallelism to be had there, which is itself worth surfacing).
//! Computed once per graph and cached on [`FactorGraph`].

use super::stats::ColoringStats;
use super::FactorGraph;

/// A proper coloring of the variable-adjacency graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Coloring {
    colors: Vec<u32>,
    classes: Vec<Vec<u32>>,
}

impl Coloring {
    /// Welsh–Powell greedy coloring of `graph`'s variable adjacency.
    pub fn compute(graph: &FactorGraph) -> Self {
        let n = graph.n();
        // Variable adjacency from the factor structure: every pair of
        // variables co-occurring in a factor is an edge (both directions;
        // sort + dedup below collapses multi-edges from parallel factors).
        let mut neighbors: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut vars_scratch: Vec<u32> = Vec::new();
        for f in graph.factors() {
            vars_scratch.clear();
            f.for_each_var(|v| vars_scratch.push(v as u32));
            for (a, &va) in vars_scratch.iter().enumerate() {
                for &vb in &vars_scratch[a + 1..] {
                    if va != vb {
                        neighbors[va as usize].push(vb);
                        neighbors[vb as usize].push(va);
                    }
                }
            }
        }
        for adj in neighbors.iter_mut() {
            adj.sort_unstable();
            adj.dedup();
        }

        // Degree-descending visit order (ties broken by index for
        // determinism).
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(neighbors[i as usize].len()));

        const UNCOLORED: u32 = u32::MAX;
        let mut colors = vec![UNCOLORED; n];
        let mut used = Vec::new(); // used[c] == generation marker
        let mut generation = 0u32;
        for &i in &order {
            generation += 1;
            for &nb in &neighbors[i as usize] {
                let c = colors[nb as usize];
                if c != UNCOLORED {
                    if used.len() <= c as usize {
                        used.resize(c as usize + 1, 0);
                    }
                    used[c as usize] = generation;
                }
            }
            let mut c = 0u32;
            while (c as usize) < used.len() && used[c as usize] == generation {
                c += 1;
            }
            colors[i as usize] = c;
        }

        let num_colors = colors.iter().map(|&c| c + 1).max().unwrap_or(0) as usize;
        let mut classes: Vec<Vec<u32>> = vec![Vec::new(); num_colors];
        for (i, &c) in colors.iter().enumerate() {
            classes[c as usize].push(i as u32);
        }
        Self { colors, classes }
    }

    /// The color of variable `i`.
    #[inline]
    pub fn color(&self, i: usize) -> u32 {
        self.colors[i]
    }

    /// Number of colors used.
    pub fn num_colors(&self) -> usize {
        self.classes.len()
    }

    /// The color classes: `classes()[c]` lists the variables with color
    /// `c`, in increasing index order.
    pub fn classes(&self) -> &[Vec<u32>] {
        &self.classes
    }

    /// The variables of color `c`.
    pub fn class(&self, c: usize) -> &[u32] {
        &self.classes[c]
    }

    /// Summary statistics for reports and the metrics surface.
    pub fn stats(&self) -> ColoringStats {
        ColoringStats {
            num_colors: self.num_colors(),
            largest_class: self.classes.iter().map(Vec::len).max().unwrap_or(0),
            smallest_class: self.classes.iter().map(Vec::len).min().unwrap_or(0),
        }
    }

    /// Check properness against the graph that produced this coloring:
    /// no factor may touch two variables of the same color.
    pub fn is_proper(&self, graph: &FactorGraph) -> bool {
        let mut vars = Vec::new();
        for f in graph.factors() {
            vars.clear();
            f.for_each_var(|v| vars.push(v));
            for (a, &va) in vars.iter().enumerate() {
                for &vb in &vars[a + 1..] {
                    if va != vb && self.colors[va] == self.colors[vb] {
                        return false;
                    }
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::graph::FactorGraphBuilder;

    #[test]
    fn proper_on_paper_ising_and_potts() {
        // Satellite requirement: no two adjacent variables share a color
        // on the paper's §B models. Both are complete graphs, so the
        // greedy coloring must also degenerate to n singleton classes.
        for g in [models::paper_ising().graph, models::paper_potts().graph] {
            let c = g.coloring();
            assert!(c.is_proper(&g));
            assert_eq!(c.num_colors(), g.n());
        }
    }

    #[test]
    fn grid_uses_two_colors() {
        // A 4-neighbor grid is bipartite: the greedy coloring on the
        // degree-ordered visit finds the 2-coloring.
        let g = models::ising_grid_local(8, 0.4);
        let c = g.coloring();
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 2);
        let s = c.stats();
        assert_eq!(s.num_colors, 2);
        assert_eq!(s.largest_class + s.smallest_class, g.n());
    }

    #[test]
    fn classes_partition_variables() {
        let g = models::potts_random(60, 3, 8, 0.5, 7);
        let c = g.coloring();
        assert!(c.is_proper(&g));
        let total: usize = c.classes().iter().map(Vec::len).sum();
        assert_eq!(total, g.n());
        for (color, class) in c.classes().iter().enumerate() {
            assert!(!class.is_empty(), "empty color class {color}");
            for &v in class {
                assert_eq!(c.color(v as usize), color as u32);
            }
        }
    }

    #[test]
    fn multipartite_colors_match_parts() {
        // The parallel bench workload: complete 5-partite graph, one
        // color per part.
        let g = models::ising_multipartite(5, 8, 2.0);
        let c = g.coloring();
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 5);
        let s = c.stats();
        assert_eq!((s.largest_class, s.smallest_class), (8, 8));
    }

    #[test]
    fn isolated_variables_share_one_color() {
        // Variables untouched by any factor are mutually non-adjacent.
        let mut b = FactorGraphBuilder::new(4, 2);
        b.add_potts_pair(0, 1, 0.5);
        let g = b.build();
        let c = g.coloring();
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 2);
        assert_eq!(c.color(2), c.color(3));
        assert_ne!(c.color(0), c.color(1));
    }

    #[test]
    fn higher_arity_table_factor_separates_all_its_vars() {
        let mut b = FactorGraphBuilder::new(3, 2);
        b.add_table(vec![0, 1, 2], vec![0.0; 8]);
        let g = b.build();
        let c = g.coloring();
        assert!(c.is_proper(&g));
        assert_eq!(c.num_colors(), 3);
    }
}
