//! Model zoo: the paper's §B synthetic models plus workload generators
//! for the Table-1 sweeps and the exact-chain validation suite.
//!
//! Conventions (shared with python/compile/model.py — see the docstring
//! there for how the paper's reported constants pin them down): one factor
//! per *unordered* pair {i, j}, with
//!
//! * Potts:  φ_ij = β A_ij δ(x_i, x_j),       M_φ = β A_ij
//! * Ising:  φ_ij = β A_ij (s_i s_j + 1),      M_φ = 2 β A_ij
//!
//! where A_ij = exp(−γ d_ij²) on the grid. Paper constants reproduced by
//! these builders (asserted in tests): Ising β=1: L = 2.21, Ψ = 416.1;
//! Potts β=4.6: L = 5.09, Ψ = 957.1.

use super::{FactorGraph, FactorGraphBuilder};
use crate::rng::{Pcg64, Rng};

/// A dense pairwise model: the factor graph plus the dense matrices the
/// XLA backend feeds the AOT kernels.
#[derive(Clone, Debug)]
pub struct DenseModel {
    /// The factor graph (source of truth for the samplers).
    pub graph: FactorGraph,
    /// Row-major n×n kernel weight matrix W with zero diagonal, defined so
    /// that the conditional energies are ε_u(i) = β Σ_j W_ij δ(u, x_j).
    /// (W = A for Potts, W = 2A for Ising.)
    pub kernel_weights: Vec<f64>,
    /// Inverse temperature β (fed to the XLA kernels as a scalar).
    pub beta: f64,
    /// Grid side length N (n = N²).
    pub grid_n: usize,
}

impl DenseModel {
    /// Conditional energies of variable `i` straight from the dense
    /// weight row: `out[u] = β Σ_j W[i,j] δ(u, x_j)`.
    ///
    /// Identical values to `graph.cond_energies_fast` (asserted in tests)
    /// but reads one contiguous f64 row instead of chasing Δ factor
    /// objects — the production hot path for dense models (§Perf).
    #[inline]
    pub fn cond_energies_row(&self, state: &[u16], i: usize, out: &mut [f64]) {
        let n = self.graph.n();
        debug_assert_eq!(out.len(), self.graph.domain_size() as usize);
        out.fill(0.0);
        let row = &self.kernel_weights[i * n..(i + 1) * n];
        for (j, &w) in row.iter().enumerate() {
            out[state[j] as usize] += w;
        }
        // W has a zero diagonal, so x_i's own bucket got += 0 — no fixup.
        for e in out.iter_mut() {
            *e *= self.beta;
        }
    }
}

/// Gaussian-RBF interaction matrix A on an N×N grid (paper §B):
/// `A_ij = exp(−γ ||pos_i − pos_j||²)` for i ≠ j, `A_ii = 0`. Row-major.
pub fn rbf_interactions(grid_n: usize, gamma: f64) -> Vec<f64> {
    let n = grid_n * grid_n;
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        let (ri, ci) = ((i / grid_n) as f64, (i % grid_n) as f64);
        for j in 0..n {
            if i == j {
                continue;
            }
            let (rj, cj) = ((j / grid_n) as f64, (j % grid_n) as f64);
            let d2 = (ri - rj).powi(2) + (ci - cj).powi(2);
            a[i * n + j] = (-gamma * d2).exp();
        }
    }
    a
}

/// The paper's §B Ising model: fully connected N×N grid, RBF interactions,
/// D = 2 (spins ±1 encoded {0, 1}).
pub fn ising_rbf(grid_n: usize, beta: f64, gamma: f64) -> DenseModel {
    let n = grid_n * grid_n;
    let a = rbf_interactions(grid_n, gamma);
    let mut b = FactorGraphBuilder::new(n, 2);
    for i in 0..n {
        for j in (i + 1)..n {
            b.add_ising_pair(i as u32, j as u32, beta * a[i * n + j]);
        }
    }
    let kernel_weights = a.iter().map(|&v| 2.0 * v).collect();
    DenseModel {
        graph: b.build(),
        kernel_weights,
        beta,
        grid_n,
    }
}

/// The paper's §B Potts model: fully connected N×N grid, RBF interactions,
/// domain size `d` (paper uses D = 10).
pub fn potts_rbf(grid_n: usize, d: u16, beta: f64, gamma: f64) -> DenseModel {
    let n = grid_n * grid_n;
    let a = rbf_interactions(grid_n, gamma);
    let mut b = FactorGraphBuilder::new(n, d);
    for i in 0..n {
        for j in (i + 1)..n {
            b.add_potts_pair(i as u32, j as u32, beta * a[i * n + j]);
        }
    }
    DenseModel {
        graph: b.build(),
        kernel_weights: a,
        beta,
        grid_n,
    }
}

/// Paper defaults: Ising 20×20, β = 1.0, γ = 1.5 (L = 2.21, Ψ = 416.1).
pub fn paper_ising() -> DenseModel {
    ising_rbf(20, 1.0, 1.5)
}

/// Paper defaults: Potts 20×20, D = 10, β = 4.6, γ = 1.5
/// (L = 5.09, Ψ = 957.1).
pub fn paper_potts() -> DenseModel {
    potts_rbf(20, 10, 4.6, 1.5)
}

/// Classic 4-neighbor grid Ising (sparse): a contrast workload where Δ is
/// tiny and minibatching cannot win — used in ablation benches.
pub fn ising_grid_local(grid_n: usize, beta: f64) -> FactorGraph {
    let n = grid_n * grid_n;
    let mut b = FactorGraphBuilder::new(n, 2);
    for r in 0..grid_n {
        for c in 0..grid_n {
            let i = (r * grid_n + c) as u32;
            if c + 1 < grid_n {
                b.add_ising_pair(i, i + 1, beta);
            }
            if r + 1 < grid_n {
                b.add_ising_pair(i, i + grid_n as u32, beta);
            }
        }
    }
    b.build()
}

/// Random sparse pairwise Potts graph: each variable gets ~`degree`
/// neighbors with i.i.d. Uniform(0, max_w] weights. For coordinator and
/// failure-injection tests.
pub fn potts_random(n: usize, d: u16, degree: usize, max_w: f64, seed: u64) -> FactorGraph {
    assert!(degree < n);
    let mut rng = Pcg64::seeded(seed);
    let mut b = FactorGraphBuilder::new(n, d);
    let mut seen = std::collections::HashSet::new();
    for i in 0..n {
        for _ in 0..degree.div_ceil(2) {
            let mut j = rng.index(n);
            while j == i {
                j = rng.index(n);
            }
            let (lo, hi) = (i.min(j), i.max(j));
            if seen.insert((lo, hi)) {
                b.add_potts_pair(lo as u32, hi as u32, rng.f64_open() * max_w);
            }
        }
    }
    b.build()
}

/// Table-1 workload (fixed L): a fully connected Potts graph over `n`
/// variables where every pair weight is `l_target / (n - 1)` — so
/// Δ = n − 1 grows with n while L = l_target stays constant
/// (Ψ = n·l_target/2 grows). Sweeping n isolates the Δ-dependence of
/// Gibbs O(DΔ) vs MGPMH O(DL² + Δ).
pub fn table1_workload(n: usize, d: u16, l_target: f64) -> FactorGraph {
    assert!(n >= 2);
    let w = l_target / (n - 1) as f64;
    let mut b = FactorGraphBuilder::new(n, d);
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            b.add_potts_pair(i, j, w);
        }
    }
    b.build()
}

/// Table-1 workload (fixed Ψ): a fully connected Potts graph where every
/// pair weight is `2·psi_target / (n(n−1))` — the paper's "very large
/// number of low-energy factors" regime. Δ = n − 1 grows while
/// Ψ = psi_target stays constant (and L = 2Ψ/n shrinks), so MIN-Gibbs's
/// O(DΨ²) and DoubleMIN's O(DL² + Ψ²) costs are provably flat in Δ.
pub fn table1_workload_fixed_psi(n: usize, d: u16, psi_target: f64) -> FactorGraph {
    assert!(n >= 2);
    let w = 2.0 * psi_target / (n as f64 * (n - 1) as f64);
    let mut b = FactorGraphBuilder::new(n, d);
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            b.add_potts_pair(i, j, w);
        }
    }
    b.build()
}

/// Complete multipartite Ising workload for the chromatic parallel
/// executor: `parts` blocks of `per_part` variables each, every
/// cross-block pair connected, no within-block edges. Δ =
/// (parts − 1)·per_part, and the variable-adjacency coloring has exactly
/// `parts` classes of `per_part` variables — big color classes over a
/// high-degree model, the regime where sweeping a class in parallel
/// pays. Uniform weights scaled so L = `l_target` (Ising M_φ = 2w).
pub fn ising_multipartite(parts: usize, per_part: usize, l_target: f64) -> FactorGraph {
    assert!(parts >= 2 && per_part >= 1);
    let degree = (parts - 1) * per_part;
    let w = l_target / (2.0 * degree as f64);
    let n = parts * per_part;
    let mut b = FactorGraphBuilder::new(n, 2);
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            if i as usize / per_part != j as usize / per_part {
                b.add_ising_pair(i, j, w);
            }
        }
    }
    b.build()
}

/// Tiny random model with enumerable state space (for the exact-chain
/// spectral validation): fully connected Potts over `n ≤ 8` variables
/// with Uniform(0, max_w] weights.
pub fn tiny_random(n: usize, d: u16, max_w: f64, seed: u64) -> FactorGraph {
    assert!(n <= 8, "state space must stay enumerable");
    let mut rng = Pcg64::seeded(seed);
    let mut b = FactorGraphBuilder::new(n, d);
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            b.add_potts_pair(i, j, rng.f64_open() * max_w);
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_matrix_properties() {
        let a = rbf_interactions(4, 1.5);
        let n = 16;
        for i in 0..n {
            assert_eq!(a[i * n + i], 0.0);
            for j in 0..n {
                assert!((a[i * n + j] - a[j * n + i]).abs() < 1e-15);
            }
        }
        // neighbors: d² = 1
        assert!((a[1] - (-1.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn paper_ising_constants() {
        // Paper §2: L = 2.21, Ψ = 416.1 for the 20×20 RBF Ising at β=1.
        let m = paper_ising();
        let s = m.graph.stats();
        assert_eq!(m.graph.n(), 400);
        assert_eq!(s.delta, 399);
        assert!((s.psi - 416.1).abs() < 0.2, "psi = {}", s.psi);
        assert!((s.l - 2.21).abs() < 0.01, "l = {}", s.l);
    }

    #[test]
    fn paper_potts_constants() {
        // Paper §3: L = 5.09, Ψ = 957.1 for the 20×20 RBF Potts at β=4.6.
        let m = paper_potts();
        let s = m.graph.stats();
        assert_eq!(m.graph.n(), 400);
        assert_eq!(m.graph.domain_size(), 10);
        assert!((s.psi - 957.1).abs() < 0.5, "psi = {}", s.psi);
        assert!((s.l - 5.09).abs() < 0.01, "l = {}", s.l);
        // The regime the paper targets: L² ≪ Δ.
        assert!(s.l * s.l < s.delta as f64 / 10.0);
    }

    #[test]
    fn kernel_weights_reproduce_cond_energies() {
        // ε_u(i) from the dense kernel weights must equal the factor-graph
        // conditional energies — this is the invariant that makes the
        // XLA backend interchangeable with the native path.
        let m = potts_rbf(3, 4, 2.0, 1.0);
        let n = m.graph.n();
        let mut rng = Pcg64::seeded(5);
        let mut state: Vec<u16> = (0..n).map(|_| rng.index(4) as u16).collect();
        let mut want = vec![0.0; 4];
        for i in 0..n {
            m.graph.cond_energies_fast(&mut state, i, &mut want);
            for u in 0..4usize {
                let got: f64 = (0..n)
                    .filter(|&j| state[j] as usize == u && j != i)
                    .map(|j| m.beta * m.kernel_weights[i * n + j])
                    .sum();
                assert!(
                    (got - want[u]).abs() < 1e-10,
                    "i={i} u={u}: {got} vs {}",
                    want[u]
                );
            }
        }
    }

    #[test]
    fn grid_local_degree() {
        let g = ising_grid_local(5, 0.4);
        assert_eq!(g.stats().delta, 4);
        assert_eq!(g.num_factors(), 2 * 5 * 4);
    }

    #[test]
    fn table1_workload_controls_l() {
        for &n in &[10, 50, 200] {
            let g = table1_workload(n, 4, 3.0);
            let s = g.stats();
            assert_eq!(s.delta, n - 1);
            assert!((s.l - 3.0).abs() < 1e-9, "n={n}: l={}", s.l);
            assert!((s.psi - 3.0 * n as f64 / 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn table1_workload_fixed_psi_controls_psi() {
        for &n in &[10, 50, 200] {
            let g = table1_workload_fixed_psi(n, 4, 8.0);
            let s = g.stats();
            assert_eq!(s.delta, n - 1);
            assert!((s.psi - 8.0).abs() < 1e-9, "n={n}: psi={}", s.psi);
            assert!((s.l - 16.0 / n as f64).abs() < 1e-9, "n={n}: l={}", s.l);
        }
    }

    #[test]
    fn multipartite_degree_and_l() {
        let g = ising_multipartite(5, 10, 2.0);
        let s = g.stats();
        assert_eq!(g.n(), 50);
        assert_eq!(s.delta, 40);
        assert!((s.l - 2.0).abs() < 1e-9, "l = {}", s.l);
        // Every variable sees all 40 cross-part neighbors exactly once.
        assert_eq!(g.num_factors(), 50 * 40 / 2);
    }

    #[test]
    fn random_graphs_deterministic_by_seed() {
        let a = potts_random(30, 3, 6, 1.0, 7);
        let b = potts_random(30, 3, 6, 1.0, 7);
        assert_eq!(a.num_factors(), b.num_factors());
        let c = potts_random(30, 3, 6, 1.0, 8);
        // different seed should (overwhelmingly) give a different graph
        assert!(a.num_factors() != c.num_factors() || {
            let s: Vec<u16> = vec![0; 30];
            (a.total_energy(&s) - c.total_energy(&s)).abs() > 1e-12
        });
    }
}
