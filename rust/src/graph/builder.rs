//! Incremental factor-graph construction with validation.

use super::{Factor, FactorGraph};

/// Builder for [`FactorGraph`]: collect factors, then `build()`.
#[derive(Clone, Debug)]
pub struct FactorGraphBuilder {
    n: usize,
    d: u16,
    factors: Vec<Factor>,
}

impl FactorGraphBuilder {
    /// Start a graph over `n` variables with shared domain `{0, .., d-1}`.
    pub fn new(n: usize, d: u16) -> Self {
        assert!(n > 0, "need at least one variable");
        assert!(d >= 2, "domain size must be >= 2");
        Self {
            n,
            d,
            factors: Vec::new(),
        }
    }

    fn check_var(&self, v: u32) {
        assert!(
            (v as usize) < self.n,
            "variable {v} out of range (n = {})",
            self.n
        );
    }

    /// Add `w * delta(x_i, x_j)`; w must be ≥ 0 and finite.
    pub fn add_potts_pair(&mut self, i: u32, j: u32, w: f64) -> &mut Self {
        self.check_var(i);
        self.check_var(j);
        assert!(i != j, "potts pair needs distinct variables");
        assert!(w >= 0.0 && w.is_finite(), "weight must be >= 0, got {w}");
        self.factors.push(Factor::PottsPair { i, j, w });
        self
    }

    /// Add `w * (s_i s_j + 1)` (spins ±1 encoded as {0,1}); requires D = 2.
    pub fn add_ising_pair(&mut self, i: u32, j: u32, w: f64) -> &mut Self {
        assert_eq!(self.d, 2, "ising pairs require domain size 2");
        self.check_var(i);
        self.check_var(j);
        assert!(i != j, "ising pair needs distinct variables");
        assert!(w >= 0.0 && w.is_finite(), "weight must be >= 0, got {w}");
        self.factors.push(Factor::IsingPair { i, j, w });
        self
    }

    /// Add a dense non-negative table factor over `vars` (row-major,
    /// last variable fastest). Table length must be D^arity.
    pub fn add_table(&mut self, vars: Vec<u32>, table: Vec<f64>) -> &mut Self {
        assert!(!vars.is_empty() && vars.len() <= 4, "table arity must be 1..=4");
        for &v in &vars {
            self.check_var(v);
        }
        let want = (self.d as usize).pow(vars.len() as u32);
        assert_eq!(
            table.len(),
            want,
            "table length {} != D^arity = {want}",
            table.len()
        );
        assert!(
            table.iter().all(|&v| v >= 0.0 && v.is_finite()),
            "table entries must be non-negative and finite"
        );
        self.factors.push(Factor::Table {
            vars,
            d: self.d,
            table,
        });
        self
    }

    /// Number of factors added so far.
    pub fn num_factors(&self) -> usize {
        self.factors.len()
    }

    /// Finalize: compute CSR adjacency and Definition-1 statistics.
    pub fn build(self) -> FactorGraph {
        assert!(
            !self.factors.is_empty(),
            "graph must have at least one factor"
        );
        FactorGraph::from_parts(self.n, self.d, self.factors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_mixed_graph() {
        let mut b = FactorGraphBuilder::new(3, 4);
        b.add_potts_pair(0, 1, 1.0)
            .add_potts_pair(1, 2, 2.0)
            .add_table(vec![0], vec![0.0, 0.1, 0.2, 0.3]);
        assert_eq!(b.num_factors(), 3);
        let g = b.build();
        assert_eq!(g.n(), 3);
        assert_eq!(g.num_factors(), 3);
        assert_eq!(g.stats().delta, 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_var() {
        let mut b = FactorGraphBuilder::new(2, 2);
        b.add_potts_pair(0, 5, 1.0);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn rejects_self_pair() {
        let mut b = FactorGraphBuilder::new(2, 2);
        b.add_potts_pair(1, 1, 1.0);
    }

    #[test]
    #[should_panic(expected = "domain size 2")]
    fn rejects_ising_with_large_domain() {
        let mut b = FactorGraphBuilder::new(2, 3);
        b.add_ising_pair(0, 1, 1.0);
    }

    #[test]
    #[should_panic(expected = "table length")]
    fn rejects_bad_table_len() {
        let mut b = FactorGraphBuilder::new(2, 3);
        b.add_table(vec![0, 1], vec![1.0; 8]);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_table() {
        let mut b = FactorGraphBuilder::new(1, 2);
        b.add_table(vec![0], vec![1.0, -0.5]);
    }

    #[test]
    #[should_panic(expected = "at least one factor")]
    fn rejects_empty_graph() {
        FactorGraphBuilder::new(2, 2).build();
    }
}
