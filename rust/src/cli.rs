//! Command-line launcher.
//!
//! Subcommands (clap is not in the offline dependency set; parsing is
//! first-party):
//!
//! ```text
//! mbgibbs sample --config cfg.toml      run an experiment from a config
//! mbgibbs fig1|fig2a|fig2b|fig2c        regenerate a paper figure
//! mbgibbs table1                        regenerate the Table-1 cost sweep
//! mbgibbs validate                      numeric checks of Theorems 2/4
//! mbgibbs check-artifacts               XLA vs native energy parity
//! mbgibbs info                          paper-model statistics (Δ, L, Ψ)
//! mbgibbs metrics --snapshot FILE       pretty-print a saved metrics snapshot
//! mbgibbs serve --config cfg.toml       run the persistent inference service
//! mbgibbs query --addr HOST:PORT        query a running service
//! ```
//!
//! Common flags: `--iters N`, `--out DIR`, `--seed S`, `--quick`.
//!
//! Observability flags for `sample`: `--metrics-out PATH` writes an
//! end-of-run JSON snapshot (plus a Prometheus text sibling `PATH.prom`),
//! `--metrics-every SECS` additionally flushes both files periodically
//! during the run, `--progress N` prints per-chain progress lines, and
//! `--resume` continues from `output_dir/checkpoints/`.
//!
//! Adaptive control flags for `sample` and `serve`: `--adapt [POLICY]`
//! turns on the per-chain controller (policies: `target-accept`,
//! `eval-budget`), `--target-accept X` sets the acceptance target, and
//! `--adapt-every N` the review cadence. `sample` layers them over the
//! `[control]` section, `serve` over `[service.adapt]`. See
//! `docs/ADAPTIVE.md`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write as IoWrite};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::{
    exact_distribution, gibbs_transition_matrix, mgpmh_transition_matrix,
    spectral_gap_reversible,
};
use crate::bench::figures::{emit_figure, FigureParams};
use crate::bench::report::{fmt_seconds, Table};
use crate::bench::timer::{bench_iter, BenchConfig};
use crate::bench::workload;
use crate::config::ExperimentConfig;
use crate::control::ControlPolicy;
use crate::coordinator::{run_chains, RunOptions, RunSpec};
use crate::graph::models;
use crate::metrics::{expose, MetricsHub, Snapshot, Unit};
use crate::rng::Pcg64;
use crate::runtime::{backend::parity_report, ArtifactStore, XlaDenseBackend};
use crate::service::{PoolConfig, QueryCacheConfig, QueryDefaults, Service, ServiceOptions};

/// Parsed command line: subcommand plus `--key value` / `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand name.
    pub command: String,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        if let Some(cmd) = iter.next() {
            if cmd.starts_with('-') {
                bail!("expected a subcommand before {cmd:?}");
            }
            args.command = cmd;
        }
        while let Some(tok) = iter.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument {tok:?}"))?;
            if key.is_empty() {
                bail!("empty option name");
            }
            match iter.peek() {
                Some(next) if !next.starts_with("--") => {
                    let value = iter.next().unwrap();
                    args.options.insert(key.to_string(), value);
                }
                _ => args.flags.push(key.to_string()),
            }
        }
        Ok(args)
    }

    /// Option value with default.
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .with_context(|| format!("--{key} must be an integer, got {v:?}")),
        }
    }

    /// Float option; `None` when absent.
    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .with_context(|| format!("--{key} must be a number, got {v:?}")),
        }
    }

    /// Presence of a bare flag.
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    /// Output directory option.
    pub fn out_dir(&self) -> PathBuf {
        PathBuf::from(
            self.options
                .get("out")
                .map(String::as_str)
                .unwrap_or("bench_out"),
        )
    }
}

/// Figure parameters derived from common flags.
fn figure_params(args: &Args) -> Result<FigureParams> {
    let mut p = if args.has_flag("quick") {
        FigureParams::quick()
    } else {
        FigureParams::default()
    };
    p.iters = args.opt_u64("iters", p.iters)?;
    p.record_every = args.opt_u64("record-every", p.record_every)?;
    p.seed = args.opt_u64("seed", p.seed)?;
    Ok(p)
}

/// Entry point used by main(); returns the process exit code.
pub fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    match args.command.as_str() {
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        "sample" => cmd_sample(&args),
        "fig1" => {
            let (m, specs) = workload::fig1_workload();
            emit_figure("figure1 min-gibbs ising", &m, &specs, &figure_params(&args)?, &args.out_dir())?;
            Ok(())
        }
        "fig2a" => {
            let (m, specs) = workload::fig2a_workload();
            emit_figure("figure2a local minibatch ising", &m, &specs, &figure_params(&args)?, &args.out_dir())?;
            Ok(())
        }
        "fig2b" => {
            let (m, specs) = workload::fig2b_workload();
            emit_figure("figure2b mgpmh potts", &m, &specs, &figure_params(&args)?, &args.out_dir())?;
            Ok(())
        }
        "fig2c" => {
            let (m, specs) = workload::fig2c_workload();
            emit_figure("figure2c doublemin potts", &m, &specs, &figure_params(&args)?, &args.out_dir())?;
            Ok(())
        }
        "table1" => cmd_table1(&args),
        "validate" => cmd_validate(&args),
        "check-artifacts" => cmd_check_artifacts(&args),
        "info" => cmd_info(),
        "metrics" => cmd_metrics(&args),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        other => bail!("unknown subcommand {other:?} (try `mbgibbs help`)"),
    }
}

fn print_help() {
    println!(
        "mbgibbs — Minibatch Gibbs Sampling on Large Graphical Models\n\
         (De Sa, Chen & Wong, ICML 2018)\n\n\
         USAGE: mbgibbs <command> [--iters N] [--out DIR] [--seed S] [--quick]\n\n\
         COMMANDS:\n\
         \x20 sample --config FILE   run an experiment described by a TOML config\n\
         \x20 fig1                   Figure 1: MIN-Gibbs vs Gibbs on the Ising model\n\
         \x20 fig2a                  Figure 2(a): Local Minibatch Gibbs (Ising)\n\
         \x20 fig2b                  Figure 2(b): MGPMH (Potts)\n\
         \x20 fig2c                  Figure 2(c): DoubleMIN-Gibbs (Potts)\n\
         \x20 table1                 Table 1: per-iteration cost sweep over Δ\n\
         \x20 validate               numeric validation of Theorems 2 and 4\n\
         \x20 check-artifacts        XLA kernels vs native energies parity check\n\
         \x20 info                   paper-model statistics (Δ, L, Ψ)\n\
         \x20 metrics --snapshot F   pretty-print a saved metrics snapshot (JSON)\n\
         \x20 serve --config FILE    persistent inference service (docs/SERVICE.md);\n\
         \x20                        overrides: --port --pool --workers --seed --resume;\n\
         \x20                        adaptive pool chains: --adapt [POLICY]\n\
         \x20                        --target-accept X --adapt-every N\n\
         \x20 query --addr H:P       query a running service; --type status (default) |\n\
         \x20                        marginal | conditional | metrics | shutdown,\n\
         \x20                        --var N, --evidence \"i=v,j=v\", --burn-in N,\n\
         \x20                        --samples N, --no-cache (bypass the\n\
         \x20                        conditional-result cache)\n\n\
         SAMPLE OBSERVABILITY:\n\
         \x20 --metrics-out PATH     write end-of-run metrics as JSON (+ PATH.prom)\n\
         \x20 --metrics-every SECS   also flush the metrics files periodically\n\
         \x20 --progress N           per-chain progress line every N iterations\n\
         \x20 --resume               resume chains from output_dir/checkpoints/\n\
         \x20 --workers N            within-chain worker threads (chromatic sweeps;\n\
         \x20                        0 = serial random scan; see docs/PARALLEL.md)\n\n\
         SAMPLE ADAPTIVE CONTROL:\n\
         \x20 --adapt [POLICY]       auto-tune λ/B from live metrics; POLICY is\n\
         \x20                        target-accept (default) | eval-budget | off\n\
         \x20 --target-accept X      acceptance target in (0,1) (implies --adapt)\n\
         \x20 --adapt-every N        controller review cadence in iterations"
    );
}

/// Resolve the control policy: the config's `[control]` section,
/// overridden by `--adapt [POLICY]`, `--target-accept X` (which implies
/// target-acceptance when no policy is active) and `--adapt-every N`.
fn control_policy_from(args: &Args, cfg: &ExperimentConfig) -> Result<ControlPolicy> {
    apply_adapt_flags(args, cfg.control.to_policy()?)
}

/// Layer the shared `--adapt` / `--target-accept` / `--adapt-every`
/// flags over a config-derived base policy. `sample` starts from
/// `[control]`, `serve` from `[service.adapt]`; the flags behave
/// identically on both.
fn apply_adapt_flags(args: &Args, base: ControlPolicy) -> Result<ControlPolicy> {
    let mut policy = base;
    if let Some(name) = args.options.get("adapt") {
        policy = ControlPolicy::from_name(name)?;
    } else if args.has_flag("adapt") && policy.is_off() {
        policy = ControlPolicy::target_acceptance(crate::control::DEFAULT_TARGET_ACCEPT);
    }
    if let Some(target) = args.opt_f64("target-accept")? {
        policy = if policy.is_off() {
            ControlPolicy::target_acceptance(target)
        } else {
            policy.with_target(target)
        };
    }
    let every = args.opt_u64("adapt-every", 0)?;
    if every > 0 {
        policy = policy.with_adapt_every(every);
    }
    Ok(policy)
}

fn cmd_sample(args: &Args) -> Result<()> {
    let config_path = args
        .options
        .get("config")
        .ok_or_else(|| anyhow!("sample requires --config FILE"))?;
    let cfg = ExperimentConfig::load(Path::new(config_path))?;
    let (graph, _dense) = cfg.build_model()?;
    let spec = cfg.sampler_spec(&graph)?;
    let resume = args.has_flag("resume");
    let mut builder = RunSpec::builder(spec)
        .iters(args.opt_u64("iters", cfg.run.iters)?)
        .chains(cfg.run.chains)
        .seed(args.opt_u64("seed", cfg.run.seed)?)
        .record_every(cfg.run.record_every)
        .progress_every(args.opt_u64("progress", cfg.run.progress_every)?)
        .resume(resume)
        .workers(args.opt_u64("workers", cfg.parallel.workers as u64)? as usize)
        .control(control_policy_from(args, &cfg)?);
    if cfg.run.checkpoint_every > 0 || resume {
        builder = builder
            .checkpoint_every(cfg.run.checkpoint_every)
            .checkpoint_dir(cfg.run.output_dir.join("checkpoints"));
    }
    let run = builder.build()?;

    let metrics_out = args.options.get("metrics-out").map(PathBuf::from);
    let metrics_every = args.opt_u64("metrics-every", 0)?;
    if metrics_every > 0 && metrics_out.is_none() {
        bail!("--metrics-every requires --metrics-out PATH");
    }

    println!(
        "model: {} (n = {}, D = {}, Δ = {}, L = {:.3}, Ψ = {:.1})",
        cfg.model.kind,
        graph.n(),
        graph.domain_size(),
        graph.stats().delta,
        graph.stats().l,
        graph.stats().psi,
    );
    println!("sampler: {}", spec.label(&graph));
    if !run.control.is_off() {
        println!("control: {}", run.control);
    }
    if run.workers > 0 {
        println!(
            "parallel: {} workers, {} color classes",
            run.workers,
            graph.coloring().num_colors()
        );
    }

    // Background flusher: periodically snapshot the hub and rewrite the
    // metrics files so long runs can be watched from outside.
    let hub = Arc::new(MetricsHub::new());
    let stop = Arc::new(AtomicBool::new(false));
    let flusher = metrics_out.as_ref().filter(|_| metrics_every > 0).map(|path| {
        let (hub, stop, path) = (hub.clone(), stop.clone(), path.clone());
        std::thread::spawn(move || {
            let tick = Duration::from_millis(200);
            let mut since_flush = Duration::ZERO;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(tick);
                since_flush += tick;
                if since_flush >= Duration::from_secs(metrics_every) {
                    since_flush = Duration::ZERO;
                    if let Err(e) = write_metrics_files(&path, &hub.snapshot()) {
                        eprintln!("[mbgibbs] metrics flush failed: {e:#}");
                    }
                }
            }
        })
    });

    let report = run_chains(&graph, &run, &RunOptions::with_hub(hub.clone()));

    stop.store(true, Ordering::Relaxed);
    if let Some(h) = flusher {
        let _ = h.join();
    }

    let mut t = Table::new(
        "sample run",
        &["chain", "final_l2_error", "evals/iter", "steps/s", "acceptance", "seconds"],
    );
    for c in &report.chains {
        t.push_row(vec![
            c.chain.to_string(),
            format!("{:.5}", c.final_error),
            format!("{:.1}", c.factor_evals as f64 / run.iters as f64),
            format!("{:.0}", c.steps_executed as f64 / c.seconds),
            format!("{:.3}", c.acceptance),
            format!("{:.2}", c.seconds),
        ]);
    }
    println!("{}", t.render());
    println!(
        "throughput: {:.0} steps/s wall-clock aggregate, {:.0} steps/s mean per chain",
        report.steps_per_sec, report.per_chain_steps_per_sec
    );
    match (report.rhat, report.pooled_ess) {
        (Some(rhat), Some(ess)) => {
            println!("convergence: R-hat = {rhat:.4} ({} chains), pooled ESS = {ess:.0}",
                report.chains.len());
        }
        (None, Some(ess)) => {
            println!("convergence: pooled ESS = {ess:.0} (run ≥ 2 chains for R-hat)");
        }
        _ => {}
    }
    t.write_csv(&cfg.run.output_dir)?;

    if let Some(path) = &metrics_out {
        write_metrics_files(path, &report.metrics)?;
        println!(
            "metrics written to {} (and {})",
            path.display(),
            path.with_extension("prom").display()
        );
        print_metrics_tables(&report.metrics);
    }
    Ok(())
}

/// Write a snapshot as JSON at `path` plus Prometheus text at the `.prom`
/// sibling.
fn write_metrics_files(path: &Path, snap: &Snapshot) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, expose::to_json(snap))
        .with_context(|| format!("writing {}", path.display()))?;
    let prom = path.with_extension("prom");
    std::fs::write(&prom, expose::to_prometheus(snap))
        .with_context(|| format!("writing {}", prom.display()))?;
    Ok(())
}

/// Format a histogram statistic for display, honouring the unit.
fn fmt_stat(v: f64, unit: Unit) -> String {
    if !v.is_finite() {
        return "-".to_string();
    }
    match unit {
        Unit::Nanos => fmt_seconds(v * 1e-9),
        Unit::None => format!("{v:.1}"),
    }
}

/// Pretty-print a snapshot as counter/gauge/histogram tables.
fn print_metrics_tables(snap: &Snapshot) {
    if !snap.counters.is_empty() {
        let mut t = Table::new("counters", &["name", "value"]);
        for (name, v) in &snap.counters {
            t.push_row(vec![name.clone(), v.to_string()]);
        }
        println!("{}", t.render());
    }
    if !snap.gauges.is_empty() {
        let mut t = Table::new("gauges", &["name", "value"]);
        for (name, v) in &snap.gauges {
            t.push_row(vec![name.clone(), format!("{v:.4}")]);
        }
        println!("{}", t.render());
    }
    if !snap.histograms.is_empty() {
        let mut t = Table::new(
            "histograms",
            &["name", "count", "mean", "p50", "p95", "p99"],
        );
        for h in &snap.histograms {
            t.push_row(vec![
                h.name.clone(),
                h.count.to_string(),
                fmt_stat(h.mean, h.unit),
                fmt_stat(h.p50, h.unit),
                fmt_stat(h.p95, h.unit),
                fmt_stat(h.p99, h.unit),
            ]);
        }
        println!("{}", t.render());
    }
}

/// `mbgibbs metrics --snapshot FILE`: pretty-print a saved JSON snapshot.
fn cmd_metrics(args: &Args) -> Result<()> {
    let path = args
        .options
        .get("snapshot")
        .ok_or_else(|| anyhow!("metrics requires --snapshot FILE"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let snap = expose::from_json(&text)?;
    println!(
        "snapshot {path}: {} counters, {} gauges, {} histograms",
        snap.counters.len(),
        snap.gauges.len(),
        snap.histograms.len()
    );
    print_metrics_tables(&snap);
    Ok(())
}

/// `mbgibbs serve --config FILE`: run the persistent inference service
/// until SIGINT/SIGTERM or a client `shutdown` request.
fn cmd_serve(args: &Args) -> Result<()> {
    let config_path = args
        .options
        .get("config")
        .ok_or_else(|| anyhow!("serve requires --config FILE"))?;
    let cfg = ExperimentConfig::load(Path::new(config_path))?;
    let (graph, _dense) = cfg.build_model()?;
    let spec = cfg.sampler_spec(&graph)?;
    let sc = &cfg.service;
    let resume = args.has_flag("resume");

    let mut pool_cfg = PoolConfig::new(spec, args.opt_u64("pool", sc.pool as u64)? as usize);
    pool_cfg.seed = args.opt_u64("seed", cfg.run.seed)?;
    pool_cfg.workers = args.opt_u64("workers", sc.workers as u64)? as usize;
    pool_cfg.record_every = cfg.run.record_every;
    pool_cfg.publish_every = sc.publish_every;
    pool_cfg.burn_in = sc.burn_in;
    pool_cfg.window = sc.window;
    pool_cfg.resume = resume;
    pool_cfg.adapt = apply_adapt_flags(args, sc.adapt.to_policy()?)?;
    if sc.checkpoint_on_shutdown || resume {
        pool_cfg.checkpoint_dir = Some(cfg.run.output_dir.join("checkpoints"));
        pool_cfg.checkpoint_on_shutdown = sc.checkpoint_on_shutdown;
    }

    let port = args.opt_u64("port", sc.port as u64)?;
    if port > u16::MAX as u64 {
        bail!("--port must fit in a u16, got {port}");
    }
    let opts = ServiceOptions {
        host: sc.host.clone(),
        port: port as u16,
        query: QueryDefaults {
            burn_in: sc.query_burn_in,
            samples: sc.query_samples,
        },
        query_cache: QueryCacheConfig {
            enabled: sc.query_cache.enabled,
            ttl: Duration::from_millis(sc.query_cache.ttl_ms),
            capacity: sc.query_cache.capacity,
        },
        ..ServiceOptions::default()
    };

    println!(
        "model: {} (n = {}, D = {}, Δ = {})",
        cfg.model.kind,
        graph.n(),
        graph.domain_size(),
        graph.stats().delta,
    );
    println!("sampler: {}", spec.label(&graph));
    if !pool_cfg.adapt.is_off() {
        println!("control: {}", pool_cfg.adapt);
    }
    let chains = pool_cfg.chains;
    let workers = pool_cfg.workers;
    let svc = Service::start(Arc::new(graph), pool_cfg, &opts)?;
    println!(
        "serving on {} ({chains} chains, {workers} workers/chain{})",
        svc.local_addr(),
        if resume { ", resumed" } else { "" },
    );
    svc.run_until_shutdown()
}

/// Build the NDJSON request line for `mbgibbs query` from its flags.
fn build_query_line(args: &Args) -> Result<String> {
    let qtype = match args.options.get("type") {
        Some(t) => t.as_str(),
        None => "status",
    };
    let required_u64 = |key: &str| -> Result<u64> {
        let v = args
            .options
            .get(key)
            .ok_or_else(|| anyhow!("query --type {qtype} requires --{key} N"))?;
        v.parse()
            .with_context(|| format!("--{key} must be a non-negative integer, got {v:?}"))
    };
    Ok(match qtype {
        "status" => "{\"type\":\"status\"}".to_string(),
        "metrics" => "{\"type\":\"metrics\"}".to_string(),
        "shutdown" => "{\"type\":\"shutdown\"}".to_string(),
        "marginal" => format!("{{\"type\":\"marginal\",\"var\":{}}}", required_u64("var")?),
        "conditional" => {
            let var = required_u64("var")?;
            let spec = args.options.get("evidence").map(String::as_str).unwrap_or("");
            let evidence = parse_evidence(spec)?;
            let pairs: Vec<String> = evidence
                .iter()
                .map(|(site, value)| format!("\"{site}\":{value}"))
                .collect();
            let mut line = format!(
                "{{\"type\":\"conditional\",\"var\":{var},\"evidence\":{{{}}}",
                pairs.join(",")
            );
            if args.options.contains_key("burn-in") {
                line.push_str(&format!(",\"burn_in\":{}", required_u64("burn-in")?));
            }
            if args.options.contains_key("samples") {
                line.push_str(&format!(",\"samples\":{}", required_u64("samples")?));
            }
            if args.has_flag("no-cache") {
                line.push_str(",\"no_cache\":true");
            }
            line.push('}');
            line
        }
        other => bail!(
            "unknown query type {other:?} (expected status | marginal | conditional | \
             metrics | shutdown)"
        ),
    })
}

/// Parse `--evidence "0=1,3=2"` into `(site, value)` pairs.
fn parse_evidence(spec: &str) -> Result<Vec<(u64, u64)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (site, value) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("evidence entries look like SITE=VALUE, got {part:?}"))?;
        let site = site
            .trim()
            .parse()
            .with_context(|| format!("bad evidence site {:?}", site.trim()))?;
        let value = value
            .trim()
            .parse()
            .with_context(|| format!("bad evidence value {:?}", value.trim()))?;
        out.push((site, value));
    }
    Ok(out)
}

/// `mbgibbs query --addr HOST:PORT [--type ...]`: one NDJSON round trip
/// against a running service; prints the raw response line.
fn cmd_query(args: &Args) -> Result<()> {
    let addr = args
        .options
        .get("addr")
        .map(String::as_str)
        .unwrap_or("127.0.0.1:7171");
    let line = build_query_line(args)?;
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    if resp.is_empty() {
        bail!("service at {addr} closed the connection without responding");
    }
    println!("{}", resp.trim_end());
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let quick = args.has_flag("quick");
    let bench_cfg = if quick {
        BenchConfig {
            warmup_iters: 100,
            batch_iters: 500,
            batches: 5,
        }
    } else {
        BenchConfig {
            warmup_iters: 1_000,
            batch_iters: 5_000,
            batches: 10,
        }
    };
    let (mut ns, d) = workload::table1_sweep();
    if quick {
        ns.truncate(4);
    }
    let mut t = Table::new(
        "table1 per-iteration cost",
        &["sweep", "n", "delta", "sampler", "median_iter_time", "evals_per_iter"],
    );
    type BuildFn = fn(usize, u16) -> crate::graph::FactorGraph;
    type LineupFn = fn(&crate::graph::FactorGraph) -> Vec<workload::SamplerSpec>;
    let sweeps: [(&str, BuildFn, LineupFn); 2] = [
        (
            "A(Ψ=8)",
            |n, d| models::table1_workload_fixed_psi(n, d, 8.0),
            |g| workload::table1_samplers_fixed_psi(g),
        ),
        (
            "B(L=2)",
            |n, d| models::table1_workload(n, d, 2.0),
            |g| workload::table1_samplers_fixed_l(g),
        ),
    ];
    for (name, build, lineup) in sweeps {
        for &n in &ns {
            let g = build(n, d);
            for spec in lineup(&g) {
                let mut sampler = spec.build(&g);
                let mut rng = Pcg64::seeded(7);
                let mut state = vec![0u16; n];
                sampler.reset(&state, &mut rng);
                let mut evals = 0u64;
                let mut steps = 0u64;
                let summary = bench_iter(&bench_cfg, |_| {
                    let st = sampler.step(&mut state, &mut rng);
                    evals += st.factor_evals;
                    steps += 1;
                });
                t.push_row(vec![
                    name.to_string(),
                    n.to_string(),
                    g.stats().delta.to_string(),
                    spec.label(&g),
                    fmt_seconds(summary.median),
                    format!("{:.1}", evals as f64 / steps as f64),
                ]);
            }
        }
    }
    println!("{}", t.render());
    t.write_csv(&args.out_dir())?;
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let seeds = if args.has_flag("quick") { 2 } else { 5 };
    let mut t = Table::new(
        "theorem validation",
        &["model_seed", "gamma_gibbs", "gamma_mgpmh", "ratio", "bound exp(-L2/lambda)", "ok"],
    );
    let mut all_ok = true;
    for seed in 0..seeds {
        let g = models::tiny_random(3, 2, 0.6, 100 + seed);
        let s = g.stats();
        let lambda = (s.l * s.l).max(1.0);
        let pi = exact_distribution(&g);
        let gamma_gibbs = spectral_gap_reversible(&gibbs_transition_matrix(&g), &pi);
        let gamma_mgpmh =
            spectral_gap_reversible(&mgpmh_transition_matrix(&g, lambda), &pi);
        let bound = (-s.l * s.l / lambda).exp();
        let ratio = gamma_mgpmh / gamma_gibbs;
        let ok = ratio >= bound - 1e-9;
        all_ok &= ok;
        t.push_row(vec![
            (100 + seed).to_string(),
            format!("{gamma_gibbs:.5}"),
            format!("{gamma_mgpmh:.5}"),
            format!("{ratio:.4}"),
            format!("{bound:.4}"),
            ok.to_string(),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&args.out_dir())?;
    if !all_ok {
        bail!("Theorem 4 bound violated — see table");
    }
    println!("Theorem 4 spectral-gap bound holds on all sampled models.");
    Ok(())
}

fn cmd_check_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(
        args.options
            .get("artifacts")
            .map(String::as_str)
            .unwrap_or("artifacts"),
    );
    let store = ArtifactStore::open(&dir)?;
    println!("artifacts: {:?}", store.names());
    let mut worst_all = 0.0f64;
    for (name, model) in [
        ("potts", models::paper_potts()),
        ("ising", models::paper_ising()),
    ] {
        let backend = XlaDenseBackend::new(&store, &model)?;
        let worst = parity_report(&backend, &model, 2, 11)?;
        println!("{name}: max |xla − native| = {worst:.2e}");
        worst_all = worst_all.max(worst);
    }
    if worst_all > 2e-3 {
        bail!("parity check failed: deviation {worst_all:.2e} > 2e-3");
    }
    println!("parity OK (float32 tolerance)");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let mut t = Table::new(
        "paper models",
        &["model", "n", "D", "delta", "L", "psi", "paper L", "paper psi"],
    );
    let ising = models::paper_ising();
    let s = ising.graph.stats();
    t.push_row(vec![
        "ising β=1.0 γ=1.5".into(),
        ising.graph.n().to_string(),
        "2".into(),
        s.delta.to_string(),
        format!("{:.3}", s.l),
        format!("{:.1}", s.psi),
        "2.21".into(),
        "416.1".into(),
    ]);
    let potts = models::paper_potts();
    let s = potts.graph.stats();
    t.push_row(vec![
        "potts β=4.6 γ=1.5".into(),
        potts.graph.n().to_string(),
        "10".into(),
        s.delta.to_string(),
        format!("{:.3}", s.l),
        format!("{:.1}", s.psi),
        "5.09".into(),
        "957.1".into(),
    ]);
    println!("{}", t.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = parse(&["fig1", "--iters", "5000", "--quick", "--out", "x"]);
        assert_eq!(a.command, "fig1");
        assert_eq!(a.opt_u64("iters", 0).unwrap(), 5000);
        assert!(a.has_flag("quick"));
        assert_eq!(a.out_dir(), PathBuf::from("x"));
    }

    #[test]
    fn rejects_leading_flag() {
        assert!(Args::parse(vec!["--oops".to_string()]).is_err());
    }

    #[test]
    fn bad_int_reported() {
        let a = parse(&["fig1", "--iters", "lots"]);
        assert!(a.opt_u64("iters", 0).is_err());
    }

    #[test]
    fn opt_f64_parses_and_reports() {
        let a = parse(&["sample", "--target-accept", "0.65"]);
        assert_eq!(a.opt_f64("target-accept").unwrap(), Some(0.65));
        assert_eq!(a.opt_f64("absent").unwrap(), None);
        let bad = parse(&["sample", "--target-accept", "most"]);
        assert!(bad.opt_f64("target-accept").is_err());
    }

    fn empty_cfg() -> ExperimentConfig {
        ExperimentConfig::from_doc(&crate::config::TomlDoc::parse("").unwrap()).unwrap()
    }

    #[test]
    fn adapt_flags_resolve_policies() {
        // Config off + no flags → off.
        let a = parse(&["sample"]);
        assert!(control_policy_from(&a, &empty_cfg()).unwrap().is_off());
        // Bare --adapt → target-acceptance defaults.
        let a = parse(&["sample", "--adapt", "--iters", "10"]);
        assert!(matches!(
            control_policy_from(&a, &empty_cfg()).unwrap(),
            ControlPolicy::TargetAcceptance { .. }
        ));
        // Valued --adapt picks the named policy.
        let a = parse(&["sample", "--adapt", "eval-budget", "--adapt-every", "250"]);
        match control_policy_from(&a, &empty_cfg()).unwrap() {
            ControlPolicy::EvalBudget { adapt_every } => assert_eq!(adapt_every, 250),
            other => panic!("wrong policy {other:?}"),
        }
        // --target-accept alone implies the target policy.
        let a = parse(&["sample", "--target-accept", "0.8"]);
        match control_policy_from(&a, &empty_cfg()).unwrap() {
            ControlPolicy::TargetAcceptance { target, .. } => assert_eq!(target, 0.8),
            other => panic!("wrong policy {other:?}"),
        }
        // Unknown policy name is an error.
        let a = parse(&["sample", "--adapt", "nope"]);
        assert!(control_policy_from(&a, &empty_cfg()).is_err());
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert!(run(vec!["frobnicate".to_string()]).is_err());
    }

    #[test]
    fn evidence_spec_parses() {
        assert_eq!(parse_evidence("0=1, 3=2").unwrap(), vec![(0, 1), (3, 2)]);
        assert_eq!(parse_evidence("").unwrap(), vec![]);
        assert!(parse_evidence("0:1").is_err());
        assert!(parse_evidence("x=1").is_err());
        assert!(parse_evidence("0=y").is_err());
    }

    #[test]
    fn query_lines_are_built_correctly() {
        let a = parse(&["query"]);
        assert_eq!(build_query_line(&a).unwrap(), "{\"type\":\"status\"}");

        let a = parse(&["query", "--type", "marginal", "--var", "4"]);
        assert_eq!(
            build_query_line(&a).unwrap(),
            "{\"type\":\"marginal\",\"var\":4}"
        );

        let a = parse(&[
            "query",
            "--type",
            "conditional",
            "--var",
            "2",
            "--evidence",
            "0=1,3=2",
            "--samples",
            "100",
        ]);
        assert_eq!(
            build_query_line(&a).unwrap(),
            "{\"type\":\"conditional\",\"var\":2,\"evidence\":{\"0\":1,\"3\":2},\"samples\":100}"
        );

        // --no-cache rides along as a JSON field.
        let a = parse(&["query", "--type", "conditional", "--var", "1", "--no-cache"]);
        assert_eq!(
            build_query_line(&a).unwrap(),
            "{\"type\":\"conditional\",\"var\":1,\"evidence\":{},\"no_cache\":true}"
        );

        // Marginal without --var, and unknown types, are errors.
        let a = parse(&["query", "--type", "marginal"]);
        assert!(build_query_line(&a).is_err());
        let a = parse(&["query", "--type", "nope"]);
        assert!(build_query_line(&a).is_err());
    }

    #[test]
    fn serve_adapt_flags_layer_over_service_section() {
        let cfg = ExperimentConfig::from_doc(
            &crate::config::TomlDoc::parse(
                "[service.adapt]\npolicy = \"target-accept\"\ntarget_accept = 0.55",
            )
            .unwrap(),
        )
        .unwrap();
        // No flags: the section's policy stands.
        let a = parse(&["serve"]);
        match apply_adapt_flags(&a, cfg.service.adapt.to_policy().unwrap()).unwrap() {
            ControlPolicy::TargetAcceptance { target, .. } => assert_eq!(target, 0.55),
            other => panic!("wrong policy {other:?}"),
        }
        // Flags override the section.
        let a = parse(&["serve", "--adapt", "off"]);
        assert!(apply_adapt_flags(&a, cfg.service.adapt.to_policy().unwrap())
            .unwrap()
            .is_off());
        // --adapt-every layers onto the section's policy.
        let a = parse(&["serve", "--adapt-every", "750"]);
        match apply_adapt_flags(&a, cfg.service.adapt.to_policy().unwrap()).unwrap() {
            ControlPolicy::TargetAcceptance { adapt_every, .. } => assert_eq!(adapt_every, 750),
            other => panic!("wrong policy {other:?}"),
        }
    }

    #[test]
    fn serve_requires_config() {
        let err = run(vec!["serve".to_string()]).unwrap_err();
        assert!(err.to_string().contains("--config"));
    }

    #[test]
    fn info_runs() {
        run(vec!["info".to_string()]).unwrap();
    }

    #[test]
    fn metrics_requires_snapshot_option() {
        let err = run(vec!["metrics".to_string()]).unwrap_err();
        assert!(err.to_string().contains("--snapshot"));
    }

    #[test]
    fn metrics_pretty_prints_a_saved_snapshot() {
        let dir = std::env::temp_dir()
            .join(format!("mbgibbs_cli_metrics_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let hub = MetricsHub::new();
        hub.counter("demo_total").add(7);
        hub.latency("demo_latency_ns")
            .record(Duration::from_micros(3));
        let path = dir.join("snap.json");
        write_metrics_files(&path, &hub.snapshot()).unwrap();
        assert!(path.exists());
        assert!(dir.join("snap.prom").exists());
        run(vec![
            "metrics".to_string(),
            "--snapshot".to_string(),
            path.to_str().unwrap().to_string(),
        ])
        .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_stat_honours_units() {
        assert_eq!(fmt_stat(f64::NAN, Unit::None), "-");
        assert_eq!(fmt_stat(12.0, Unit::None), "12.0");
        // 1.5e9 ns = 1.5 s; exact rendering delegated to fmt_seconds.
        assert!(fmt_stat(1.5e9, Unit::Nanos).contains('s'));
    }
}
