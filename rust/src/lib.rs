//! `mbgibbs` — Minibatch Gibbs Sampling on Large Graphical Models.
//!
//! A three-layer reproduction of De Sa, Chen & Wong (ICML 2018):
//!
//! * **Layer 3 (this crate)** — the sampling runtime: factor graphs, the
//!   five samplers (Gibbs, MIN-Gibbs, Local Minibatch Gibbs, MGPMH,
//!   DoubleMIN-Gibbs), the multi-chain coordinator, analysis tools, the
//!   benchmark harness, and a PJRT executor for the AOT energy kernels.
//! * **Layer 2 (python/compile/model.py)** — JAX conditional-energy graphs
//!   for the paper's dense lattice models, lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels called by L2.
//!
//! Python never runs on the sampling path: `make artifacts` compiles the
//! kernels ahead of time and [`runtime`] loads them via the PJRT C API.
//!
//! # Quickstart
//!
//! ```no_run
//! use mbgibbs::graph::models;
//! use mbgibbs::rng::Pcg64;
//! use mbgibbs::samplers::{Sampler, MgpmhSampler};
//!
//! let model = models::paper_potts();
//! let mut rng = Pcg64::seeded(0);
//! let mut state = vec![0u16; model.graph.n()];
//! let l = model.graph.stats().l;
//! // Minibatch sampler with the paper's recommended λ = L².
//! let mut sampler = MgpmhSampler::new(&model.graph, l * l);
//! for _ in 0..10_000 {
//!     sampler.step(&mut state, &mut rng);
//! }
//! ```

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod graph;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod samplers;
pub mod service;
pub mod testutil;
