//! Seeded property-testing helpers (stand-in for `proptest`, which is not
//! available in the offline dependency set).
//!
//! The pattern: generate many random instances from a seeded [`Pcg64`],
//! run an invariant over each, and report the failing seed so the case is
//! replayable. Used across the rng/graph/sampler test suites.

use crate::graph::{FactorGraph, FactorGraphBuilder};
use crate::rng::{Pcg64, Rng};

/// Configuration for random factor-graph generation.
#[derive(Clone, Copy, Debug)]
pub struct GraphGenConfig {
    /// Inclusive variable-count range.
    pub n: (usize, usize),
    /// Inclusive domain-size range.
    pub d: (u16, u16),
    /// Maximum pair weight.
    pub max_w: f64,
    /// Probability of adding a table factor instead of a pair factor.
    pub table_prob: f64,
}

impl Default for GraphGenConfig {
    fn default() -> Self {
        Self {
            n: (2, 6),
            d: (2, 4),
            max_w: 1.0,
            table_prob: 0.2,
        }
    }
}

/// Generate one random mixed factor graph.
pub fn random_graph(rng: &mut Pcg64, cfg: &GraphGenConfig) -> FactorGraph {
    let n = cfg.n.0 + rng.index(cfg.n.1 - cfg.n.0 + 1);
    let d = cfg.d.0 + rng.index((cfg.d.1 - cfg.d.0 + 1) as usize) as u16;
    let mut b = FactorGraphBuilder::new(n, d);
    let num_factors = 1 + rng.index(2 * n);
    for _ in 0..num_factors {
        if n >= 2 && !rng.bernoulli(cfg.table_prob) {
            let i = rng.index(n) as u32;
            let mut j = rng.index(n) as u32;
            while j == i {
                j = rng.index(n) as u32;
            }
            b.add_potts_pair(i.min(j), i.max(j), rng.f64() * cfg.max_w);
        } else {
            let arity = 1 + rng.index(2.min(n));
            let mut vars: Vec<u32> = Vec::new();
            while vars.len() < arity {
                let v = rng.index(n) as u32;
                if !vars.contains(&v) {
                    vars.push(v);
                }
            }
            let len = (d as usize).pow(vars.len() as u32);
            let table: Vec<f64> = (0..len).map(|_| rng.f64() * cfg.max_w).collect();
            b.add_table(vars, table);
        }
    }
    b.build()
}

/// Run `check` over `count` random graphs; panics with the failing seed.
pub fn for_random_graphs<F>(seed: u64, count: usize, cfg: GraphGenConfig, mut check: F)
where
    F: FnMut(u64, &FactorGraph),
{
    for trial in 0..count {
        let case_seed = seed.wrapping_mul(1_000_003).wrapping_add(trial as u64);
        let mut rng = Pcg64::seeded(case_seed);
        let g = random_graph(&mut rng, &cfg);
        check(case_seed, &g);
    }
}

/// Generate a random valid state for a graph.
pub fn random_state(rng: &mut Pcg64, g: &FactorGraph) -> Vec<u16> {
    (0..g.n())
        .map(|_| rng.index(g.domain_size() as usize) as u16)
        .collect()
}

/// Assert two floats are within `tol`, with a replayable message.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64, context: &str) {
    assert!(
        (a - b).abs() <= tol,
        "{context}: |{a} - {b}| = {} > {tol}",
        (a - b).abs()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_graphs_are_valid() {
        for_random_graphs(7, 30, GraphGenConfig::default(), |seed, g| {
            assert!(g.n() >= 2 && g.n() <= 6, "seed {seed}");
            assert!(g.num_factors() >= 1, "seed {seed}");
            let s = g.stats();
            assert!(s.psi >= 0.0 && s.l <= s.psi + 1e-12, "seed {seed}");
            assert!(s.delta <= g.num_factors(), "seed {seed}");
        });
    }

    /// Property: conditional-energy paths agree on arbitrary graphs.
    #[test]
    fn cond_energy_paths_agree_property() {
        for_random_graphs(13, 40, GraphGenConfig::default(), |seed, g| {
            let mut rng = Pcg64::seeded(seed ^ 0xabcd);
            let mut state = random_state(&mut rng, g);
            let d = g.domain_size() as usize;
            let mut a = vec![0.0; d];
            let mut b = vec![0.0; d];
            for i in 0..g.n() {
                g.cond_energies_generic(&mut state, i, &mut a);
                g.cond_energies_fast(&mut state, i, &mut b);
                for u in 0..d {
                    assert_close(a[u], b[u], 1e-10, &format!("seed {seed} i={i} u={u}"));
                }
            }
        });
    }

    /// Property: total energy equals the sum of local energies divided by
    /// arity-weighted counting (each pair counted at both endpoints).
    #[test]
    fn local_energy_consistency_property() {
        let cfg = GraphGenConfig {
            table_prob: 0.0, // pairs only: each factor counted exactly twice
            ..Default::default()
        };
        for_random_graphs(17, 30, cfg, |seed, g| {
            let mut rng = Pcg64::seeded(seed ^ 0x1234);
            let state = random_state(&mut rng, g);
            let total: f64 = (0..g.n()).map(|i| g.local_energy(&state, i)).sum();
            assert_close(
                total,
                2.0 * g.total_energy(&state),
                1e-9,
                &format!("seed {seed}"),
            );
        });
    }
}
