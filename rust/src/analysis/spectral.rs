//! Spectral gap computation for reversible chains (Definition 3).
//!
//! A reversible T with stationary π is similar to the symmetric matrix
//! S = D^{1/2} T D^{−1/2} (D = diag(π)), so its eigenvalues are real and
//! computable with the cyclic Jacobi method. The spectral gap is
//! γ = λ₁ − λ₂ = 1 − λ₂.

/// Eigenvalues of a dense symmetric matrix via cyclic Jacobi rotations,
/// returned in descending order. `a` is consumed as scratch.
pub fn jacobi_eigenvalues(mut a: Vec<Vec<f64>>) -> Vec<f64> {
    let n = a.len();
    assert!(n > 0 && a.iter().all(|r| r.len() == n), "matrix must be square");
    let off = |a: &Vec<Vec<f64>>| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    s += a[i][j] * a[i][j];
                }
            }
        }
        s
    };
    let mut sweeps = 0;
    while off(&a) > 1e-22 && sweeps < 200 {
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p][q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p][p];
                let aqq = a[q][q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..n {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
            }
        }
        sweeps += 1;
    }
    let mut eig: Vec<f64> = (0..n).map(|i| a[i][i]).collect();
    eig.sort_by(|x, y| y.partial_cmp(x).unwrap());
    eig
}

/// Spectral gap γ = 1 − λ₂ of a reversible row-stochastic `t` with
/// stationary distribution `pi`. Panics if the chain is detectably
/// non-reversible (detailed-balance violation > 1e-7).
pub fn spectral_gap_reversible(t: &[Vec<f64>], pi: &[f64]) -> f64 {
    let viol = super::transition::reversibility_violation(t, pi);
    assert!(
        viol < 1e-7,
        "chain is not reversible (violation {viol}); spectral gap undefined"
    );
    let n = t.len();
    let mut s = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for j in 0..n {
            // S_ij = sqrt(pi_i / pi_j) T_ij; symmetrize vs the transpose
            // entry to kill roundoff asymmetry.
            let sij = (pi[i] / pi[j]).sqrt() * t[i][j];
            let sji = (pi[j] / pi[i]).sqrt() * t[j][i];
            s[i][j] = 0.5 * (sij + sji);
        }
    }
    let eig = jacobi_eigenvalues(s);
    debug_assert!((eig[0] - 1.0).abs() < 1e-6, "λ₁ = {} != 1", eig[0]);
    1.0 - eig[1]
}

/// Convenience: compute π by enumeration and return the gap.
pub fn spectral_gap(g: &crate::graph::FactorGraph, t: &[Vec<f64>]) -> f64 {
    let pi = super::exact_distribution(g);
    spectral_gap_reversible(t, &pi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{exact_distribution, gibbs_transition_matrix};
    use crate::graph::models;

    #[test]
    fn jacobi_diag_matrix() {
        let a = vec![
            vec![3.0, 0.0, 0.0],
            vec![0.0, -1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ];
        let eig = jacobi_eigenvalues(a);
        assert!((eig[0] - 3.0).abs() < 1e-12);
        assert!((eig[1] - 2.0).abs() < 1e-12);
        assert!((eig[2] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1
        let eig = jacobi_eigenvalues(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        assert!((eig[0] - 3.0).abs() < 1e-12);
        assert!((eig[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_trace_preserved() {
        // random symmetric 6x6: eigenvalue sum = trace
        use crate::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seeded(101);
        let n = 6;
        let mut a = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in i..n {
                let v = rng.f64() - 0.5;
                a[i][j] = v;
                a[j][i] = v;
            }
        }
        let trace: f64 = (0..n).map(|i| a[i][i]).sum();
        let eig = jacobi_eigenvalues(a);
        let sum: f64 = eig.iter().sum();
        assert!((sum - trace).abs() < 1e-9);
    }

    #[test]
    fn two_state_chain_gap() {
        // T = [[1-p, p], [q, 1-q]]: eigenvalues 1 and 1-p-q; gap = p+q.
        let (p, q) = (0.3, 0.2);
        let t = vec![vec![1.0 - p, p], vec![q, 1.0 - q]];
        let pi = vec![q / (p + q), p / (p + q)];
        let gap = spectral_gap_reversible(&t, &pi);
        assert!((gap - (p + q)).abs() < 1e-10, "gap = {gap}");
    }

    #[test]
    fn gibbs_gap_positive_and_at_most_one() {
        let g = models::tiny_random(3, 2, 0.8, 102);
        let t = gibbs_transition_matrix(&g);
        let pi = exact_distribution(&g);
        let gap = spectral_gap_reversible(&t, &pi);
        assert!(gap > 0.0 && gap <= 1.0 + 1e-9, "gap = {gap}");
    }

    #[test]
    fn stronger_interactions_shrink_gap() {
        // Higher β couples variables more strongly -> slower mixing.
        let weak = models::tiny_random(3, 2, 0.2, 103);
        let strong = models::tiny_random(3, 2, 2.5, 103); // same topology, scaled weights
        let gw = spectral_gap(&weak, &gibbs_transition_matrix(&weak));
        let gs = spectral_gap(&strong, &gibbs_transition_matrix(&strong));
        assert!(gs < gw, "strong {gs} !< weak {gw}");
    }
}
