//! Generic MCMC convergence diagnostics: autocorrelation, effective sample
//! size, and the Gelman–Rubin statistic across coordinator chains.

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64
}

/// Normalized autocorrelation ρ(k) of a scalar series at lag `k`.
pub fn autocorrelation(xs: &[f64], k: usize) -> f64 {
    let n = xs.len();
    if k >= n || n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let denom: f64 = xs.iter().map(|&x| (x - m) * (x - m)).sum();
    if denom == 0.0 {
        return if k == 0 { 1.0 } else { 0.0 };
    }
    let num: f64 = (0..n - k).map(|t| (xs[t] - m) * (xs[t + k] - m)).sum();
    num / denom
}

/// Integrated autocorrelation time τ via Geyer's initial-positive-sequence
/// truncation: τ = 1 + 2 Σ ρ(k), stopping when ρ(2j) + ρ(2j+1) ≤ 0.
pub fn integrated_autocorr_time(xs: &[f64]) -> f64 {
    let n = xs.len();
    let mut tau = 1.0;
    let mut k = 1;
    while k + 1 < n {
        let pair = autocorrelation(xs, k) + autocorrelation(xs, k + 1);
        if pair <= 0.0 {
            break;
        }
        tau += 2.0 * pair;
        k += 2;
    }
    tau.max(1.0)
}

/// Effective sample size n/τ.
pub fn effective_sample_size(xs: &[f64]) -> f64 {
    xs.len() as f64 / integrated_autocorr_time(xs)
}

/// Gelman–Rubin potential scale reduction factor R̂ over ≥ 2 chains of
/// equal length. R̂ ≈ 1 indicates convergence.
pub fn gelman_rubin(chains: &[Vec<f64>]) -> f64 {
    let m = chains.len();
    assert!(m >= 2, "need at least two chains");
    let n = chains[0].len();
    assert!(
        n >= 2 && chains.iter().all(|c| c.len() == n),
        "chains must have equal length >= 2"
    );
    let means: Vec<f64> = chains.iter().map(|c| mean(c)).collect();
    let grand = mean(&means);
    let b = n as f64 / (m as f64 - 1.0)
        * means.iter().map(|&x| (x - grand) * (x - grand)).sum::<f64>();
    let w = chains.iter().map(|c| variance(c)).sum::<f64>() / m as f64;
    if w == 0.0 {
        return 1.0;
    }
    let var_plus = (n as f64 - 1.0) / n as f64 * w + b / n as f64;
    (var_plus / w).sqrt()
}

/// Cross-chain convergence summary on per-chain scalar traces (e.g. the
/// thinned energy series ζ(x)): `(R̂, pooled ESS)`. Traces are truncated
/// to the shortest chain so mixed-length inputs (resumes, a live pool
/// mid-publish) still diagnose. `R̂` is `Some` with ≥ 2 chains and ≥ 2
/// points per chain; pooled ESS (Σ over chains of n/τ) needs only ≥ 2
/// points per chain. Degenerate windows — a non-finite energy point,
/// zero cross-chain variance, too few chains — yield `None` rather than
/// NaN, so NDJSON/Prometheus consumers see `null`, never `NaN`.
pub fn cross_chain_diagnostics(traces: &[&[f64]]) -> (Option<f64>, Option<f64>) {
    let min_len = traces.iter().map(|t| t.len()).min().unwrap_or(0);
    if min_len < 2 {
        return (None, None);
    }
    let truncated: Vec<Vec<f64>> = traces.iter().map(|t| t[..min_len].to_vec()).collect();
    // A single non-finite point would NaN-poison every moment below (or
    // worse, sneak a finite-but-meaningless τ through `max`); the whole
    // window is undiagnosable.
    if truncated.iter().any(|t| t.iter().any(|v| !v.is_finite())) {
        return (None, None);
    }
    let rhat = if truncated.len() >= 2 {
        Some(gelman_rubin(&truncated)).filter(|v| v.is_finite())
    } else {
        None
    };
    let pooled_ess = Some(truncated.iter().map(|t| effective_sample_size(t)).sum::<f64>())
        .filter(|v| v.is_finite());
    (rhat, pooled_ess)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn iid_series(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| rng.f64()).collect()
    }

    #[test]
    fn autocorr_lag0_is_one() {
        let xs = iid_series(500, 1);
        assert!((autocorrelation(&xs, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn iid_has_tau_near_one() {
        let xs = iid_series(20_000, 2);
        let tau = integrated_autocorr_time(&xs);
        assert!(tau < 1.3, "tau = {tau}");
        let ess = effective_sample_size(&xs);
        assert!(ess > 15_000.0, "ess = {ess}");
    }

    #[test]
    fn ar1_has_large_tau() {
        // AR(1) with φ = 0.95: τ ≈ (1+φ)/(1−φ) = 39.
        let mut rng = Pcg64::seeded(3);
        let mut xs = vec![0.0f64];
        for _ in 0..50_000 {
            let prev = *xs.last().unwrap();
            xs.push(0.95 * prev + (rng.f64() - 0.5));
        }
        let tau = integrated_autocorr_time(&xs);
        assert!(tau > 15.0, "tau = {tau}");
        assert!(effective_sample_size(&xs) < 5_000.0);
    }

    #[test]
    fn gelman_rubin_converged_chains() {
        let chains: Vec<Vec<f64>> = (0..4).map(|i| iid_series(5000, 10 + i)).collect();
        let r = gelman_rubin(&chains);
        assert!((r - 1.0).abs() < 0.02, "rhat = {r}");
    }

    #[test]
    fn gelman_rubin_detects_disagreement() {
        let mut chains: Vec<Vec<f64>> = (0..3).map(|i| iid_series(2000, 20 + i)).collect();
        // shift one chain far away
        for v in chains[0].iter_mut() {
            *v += 10.0;
        }
        let r = gelman_rubin(&chains);
        assert!(r > 2.0, "rhat = {r}");
    }

    #[test]
    fn cross_chain_handles_short_and_uneven_traces() {
        assert_eq!(cross_chain_diagnostics(&[]), (None, None));
        assert_eq!(cross_chain_diagnostics(&[&[1.0]]), (None, None));
        // One chain: no R̂, but an ESS.
        let a = iid_series(100, 30);
        let (rhat, ess) = cross_chain_diagnostics(&[&a]);
        assert!(rhat.is_none());
        assert!(ess.unwrap() > 0.0);
        // Uneven lengths truncate to the shortest.
        let b = iid_series(60, 31);
        let (rhat, _) = cross_chain_diagnostics(&[&a, &b]);
        let (rhat_trunc, _) = cross_chain_diagnostics(&[&a[..60], &b]);
        assert_eq!(rhat.unwrap(), rhat_trunc.unwrap());
    }

    /// Degenerate windows must come back as `None` (→ JSON `null`), not
    /// NaN: a zero-variance window keeps R̂ = 1 by the `w == 0` guard,
    /// and any non-finite energy point poisons both statistics.
    #[test]
    fn cross_chain_never_emits_nan() {
        // Constant (zero-variance) traces: R̂ hits the w == 0 guard.
        let flat = vec![2.5f64; 50];
        let (rhat, ess) = cross_chain_diagnostics(&[&flat, &flat]);
        assert_eq!(rhat, Some(1.0));
        assert!(ess.unwrap().is_finite());

        // A NaN energy point (e.g. an overflowed ζ(x)) poisons the
        // window; both statistics must clamp to None.
        let mut poisoned = iid_series(50, 40);
        poisoned[7] = f64::NAN;
        let clean = iid_series(50, 41);
        let (rhat, ess) = cross_chain_diagnostics(&[&poisoned, &clean]);
        assert_eq!(rhat, None, "NaN window must not leak an R̂");
        assert_eq!(ess, None, "NaN window must not leak an ESS");

        // Same for infinities.
        let mut inf = iid_series(50, 42);
        inf[3] = f64::INFINITY;
        let (rhat, ess) = cross_chain_diagnostics(&[&inf, &clean]);
        assert_eq!(rhat, None);
        assert_eq!(ess, None);
    }

    #[test]
    fn variance_known_values() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }
}
