//! Exact transition matrices for enumerable models.
//!
//! These are the objects Theorems 2–6 make claims about. For vanilla Gibbs
//! the matrix is exact; for MGPMH the expectation over the Poisson
//! minibatch coefficients is taken by enumerating s-vectors up to a
//! truncation point whose leftover probability mass is provably below
//! `1e-10` (rows are then closed by assigning the remainder to the
//! diagonal, which can only *shrink* the computed spectral gap — so the
//! theorem checks remain conservative).

use crate::graph::FactorGraph;
use crate::rng::special::ln_factorial;

use super::StateSpace;

/// Exact transition matrix of vanilla Gibbs (Algorithm 1), row-stochastic.
pub fn gibbs_transition_matrix(g: &FactorGraph) -> Vec<Vec<f64>> {
    let space = StateSpace::for_graph(g);
    let n = g.n();
    let d = g.domain_size() as usize;
    let size = space.len();
    let mut t = vec![vec![0.0f64; size]; size];
    let mut eps = vec![0.0f64; d];
    for idx in 0..size {
        let mut state = space.state(idx);
        for i in 0..n {
            g.cond_energies_generic(&mut state, i, &mut eps);
            let max = eps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let z: f64 = eps.iter().map(|&e| (e - max).exp()).sum();
            for u in 0..d {
                let p = (eps[u] - max).exp() / z;
                let jdx = space.with_value(idx, i, u);
                t[idx][jdx] += p / n as f64;
            }
        }
    }
    t
}

/// Poisson pmf values 0..=k_max for rate `lam`, plus leftover tail mass.
fn poisson_pmf_truncated(lam: f64, k_max: usize) -> (Vec<f64>, f64) {
    let mut pmf = Vec::with_capacity(k_max + 1);
    let mut total = 0.0;
    for k in 0..=k_max {
        let lp = if lam == 0.0 {
            if k == 0 {
                0.0
            } else {
                f64::NEG_INFINITY
            }
        } else {
            k as f64 * lam.ln() - lam - ln_factorial(k as u64)
        };
        let p = lp.exp();
        pmf.push(p);
        total += p;
    }
    (pmf, 1.0 - total)
}

/// Exact (to truncation ≤ 1e-10 per factor) transition matrix of MGPMH
/// (Algorithm 4) with average batch size `lambda`.
///
/// Cost is |Ω| · n · Π_{φ∈A[i]} (k_max+1), so this is only for tiny
/// graphs (Δ ≤ 4 or so).
pub fn mgpmh_transition_matrix(g: &FactorGraph, lambda: f64) -> Vec<Vec<f64>> {
    let space = StateSpace::for_graph(g);
    let n = g.n();
    let d = g.domain_size() as usize;
    let size = space.len();
    let l = g.stats().l;

    let mut t = vec![vec![0.0f64; size]; size];
    for i in 0..n {
        let factors: Vec<usize> = g.factors_of(i).iter().map(|&f| f as usize).collect();
        let delta_i = factors.len();
        assert!(delta_i <= 6, "enumeration explodes beyond Δ = 6");
        // Per-factor truncated Poisson pmfs.
        let mut pmfs = Vec::with_capacity(delta_i);
        for &fid in &factors {
            let rate = lambda * g.max_energy(fid) / l;
            // k_max: generous bound making tail < 1e-12 for small rates.
            let k_max = (8.0 + 6.0 * rate).ceil() as usize;
            let (pmf, tail) = poisson_pmf_truncated(rate, k_max);
            assert!(tail < 1e-10, "tail mass {tail} too large");
            pmfs.push(pmf);
        }
        // Enumerate all s-vectors via mixed-radix counting.
        let mut s_vec = vec![0usize; delta_i];
        loop {
            // probability of this s-vector
            let ps: f64 = s_vec
                .iter()
                .zip(pmfs.iter())
                .map(|(&s, pmf)| pmf[s])
                .product();
            if ps > 0.0 {
                accumulate_mgpmh_for_s(
                    g, &space, i, &factors, &s_vec, lambda, l, ps, d, &mut t,
                );
            }
            // increment mixed-radix counter
            let mut pos = 0;
            loop {
                if pos == delta_i {
                    break;
                }
                s_vec[pos] += 1;
                if s_vec[pos] < pmfs[pos].len() {
                    break;
                }
                s_vec[pos] = 0;
                pos += 1;
            }
            if pos == delta_i {
                break;
            }
        }
    }
    // Close rows: diagonal gets the remaining mass (variable-choice 1/n is
    // folded in by accumulate; truncation leftovers land here too).
    for (idx, row) in t.iter_mut().enumerate() {
        let off: f64 = row
            .iter()
            .enumerate()
            .filter(|&(j, _)| j != idx)
            .map(|(_, &v)| v)
            .sum();
        row[idx] = 1.0 - off;
    }
    t
}

#[allow(clippy::too_many_arguments)]
fn accumulate_mgpmh_for_s(
    g: &FactorGraph,
    space: &StateSpace,
    i: usize,
    factors: &[usize],
    s_vec: &[usize],
    lambda: f64,
    l: f64,
    ps: f64,
    d: usize,
    t: &mut [Vec<f64>],
) {
    let n = g.n();
    for idx in 0..space.len() {
        let mut state = space.state(idx);
        let cur = state[i] as usize;
        // proposal energies ε_u for this s-vector
        let mut eps = vec![0.0f64; d];
        for (u, slot) in eps.iter_mut().enumerate() {
            state[i] = u as u16;
            let mut sum = 0.0;
            for (&fid, &s) in factors.iter().zip(s_vec.iter()) {
                if s > 0 {
                    let m = g.max_energy(fid);
                    sum += (s as f64) * l / (lambda * m) * g.value(fid, &state);
                }
            }
            *slot = sum;
        }
        let max = eps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = eps.iter().map(|&e| (e - max).exp()).sum();

        // local energies for acceptance
        state[i] = cur as u16;
        let local_x: f64 = factors.iter().map(|&f| g.value(f, &state)).sum();
        for v in 0..d {
            if v == cur {
                continue; // self-proposal handled by row closing
            }
            state[i] = v as u16;
            let local_y: f64 = factors.iter().map(|&f| g.value(f, &state)).sum();
            let psi_v = (eps[v] - max).exp() / z;
            let a = ((local_y - local_x) + (eps[cur] - eps[v])).exp().min(1.0);
            let jdx = space.with_value(idx, i, v);
            t[idx][jdx] += ps * psi_v * a / n as f64;
        }
        state[i] = cur as u16;
    }
}

/// Verify detailed balance π(x)T(x,y) = π(y)T(y,x); returns the max
/// violation.
pub fn reversibility_violation(t: &[Vec<f64>], pi: &[f64]) -> f64 {
    let mut worst = 0.0f64;
    for (x, row) in t.iter().enumerate() {
        for (y, &txy) in row.iter().enumerate() {
            let flow_xy = pi[x] * txy;
            let flow_yx = pi[y] * t[y][x];
            worst = worst.max((flow_xy - flow_yx).abs());
        }
    }
    worst
}

/// Max |πT − π| entry: stationarity check.
pub fn stationarity_violation(t: &[Vec<f64>], pi: &[f64]) -> f64 {
    let size = pi.len();
    let mut worst = 0.0f64;
    for y in 0..size {
        let mut acc = 0.0;
        for x in 0..size {
            acc += pi[x] * t[x][y];
        }
        worst = worst.max((acc - pi[y]).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::exact_distribution;
    use crate::graph::models;

    fn rows_stochastic(t: &[Vec<f64>]) {
        for row in t {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "row sum {s}");
            assert!(row.iter().all(|&v| v >= -1e-12));
        }
    }

    #[test]
    fn gibbs_matrix_stochastic_and_reversible() {
        let g = models::tiny_random(3, 3, 0.8, 91);
        let t = gibbs_transition_matrix(&g);
        rows_stochastic(&t);
        let pi = exact_distribution(&g);
        assert!(reversibility_violation(&t, &pi) < 1e-12);
        assert!(stationarity_violation(&t, &pi) < 1e-12);
    }

    #[test]
    fn mgpmh_matrix_stochastic_reversible_stationary() {
        // Theorem 3 numerically: MGPMH is reversible wrt π.
        let g = models::tiny_random(3, 2, 0.6, 92);
        let t = mgpmh_transition_matrix(&g, 2.0);
        rows_stochastic(&t);
        let pi = exact_distribution(&g);
        assert!(
            reversibility_violation(&t, &pi) < 1e-8,
            "violation = {}",
            reversibility_violation(&t, &pi)
        );
        assert!(stationarity_violation(&t, &pi) < 1e-8);
    }

    #[test]
    fn mgpmh_approaches_gibbs_for_large_lambda() {
        let g = models::tiny_random(3, 2, 0.5, 93);
        let tg = gibbs_transition_matrix(&g);
        let tm = mgpmh_transition_matrix(&g, 60.0);
        let mut worst = 0.0f64;
        for (rg, rm) in tg.iter().zip(tm.iter()) {
            for (a, b) in rg.iter().zip(rm.iter()) {
                worst = worst.max((a - b).abs());
            }
        }
        assert!(worst < 0.05, "max entry diff {worst}");
    }

    #[test]
    fn poisson_pmf_truncation() {
        let (pmf, tail) = poisson_pmf_truncated(1.5, 30);
        assert!((pmf.iter().sum::<f64>() + tail - 1.0).abs() < 1e-12);
        assert!(tail < 1e-12);
        // zero rate: point mass at 0
        let (pmf, tail) = poisson_pmf_truncated(0.0, 5);
        assert_eq!(pmf[0], 1.0);
        assert!(pmf[1..].iter().all(|&p| p == 0.0));
        assert!(tail.abs() < 1e-12);
    }
}
