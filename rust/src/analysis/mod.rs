//! Chain analysis: exact enumeration, transition matrices, spectral gaps,
//! and convergence diagnostics.
//!
//! For models with enumerable state spaces (D^n small) this module builds
//! the *exact* objects the paper's theorems talk about — π, T, and the
//! spectral gap γ — so Theorems 2/4/6 can be validated numerically rather
//! than just cited.

pub mod diagnostics;
pub mod marginals;
pub mod spectral;
pub mod transition;

pub use marginals::MarginalEstimator;
pub use spectral::{spectral_gap, spectral_gap_reversible};
pub use transition::{gibbs_transition_matrix, mgpmh_transition_matrix};

use crate::graph::FactorGraph;

/// Enumerable state space {0,..,D-1}^n with index ↔ state conversion.
///
/// States are numbered with variable 0 as the most significant digit.
#[derive(Clone, Copy, Debug)]
pub struct StateSpace {
    n: usize,
    d: usize,
    size: usize,
}

impl StateSpace {
    /// Create; panics if D^n overflows or exceeds 2^24 (enumeration guard).
    pub fn new(n: usize, d: usize) -> Self {
        let size = d
            .checked_pow(n as u32)
            .filter(|&s| s <= (1 << 24))
            .expect("state space too large to enumerate");
        Self { n, d, size }
    }

    /// For a factor graph (n variables, domain D).
    pub fn for_graph(g: &FactorGraph) -> Self {
        Self::new(g.n(), g.domain_size() as usize)
    }

    /// Number of states D^n.
    pub fn len(&self) -> usize {
        self.size
    }

    /// True iff the space is empty (never: n ≥ 1, D ≥ 2).
    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Decode index → state vector.
    pub fn state(&self, mut idx: usize) -> Vec<u16> {
        let mut s = vec![0u16; self.n];
        for i in (0..self.n).rev() {
            s[i] = (idx % self.d) as u16;
            idx /= self.d;
        }
        s
    }

    /// Encode state vector → index.
    pub fn index(&self, state: &[u16]) -> usize {
        state
            .iter()
            .fold(0usize, |acc, &v| acc * self.d + v as usize)
    }

    /// The index obtained from `idx` by setting variable `i` to `u`.
    pub fn with_value(&self, idx: usize, i: usize, u: usize) -> usize {
        let place = self.d.pow((self.n - 1 - i) as u32);
        let cur = (idx / place) % self.d;
        idx + (u - cur).wrapping_mul(place)
    }
}

/// Exact Gibbs measure π(x) ∝ exp(ζ(x)) by full enumeration.
pub fn exact_distribution(g: &FactorGraph) -> Vec<f64> {
    let space = StateSpace::for_graph(g);
    let mut log_w: Vec<f64> = (0..space.len())
        .map(|idx| g.total_energy(&space.state(idx)))
        .collect();
    let max = log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for w in log_w.iter_mut() {
        *w = (*w - max).exp();
        z += *w;
    }
    for w in log_w.iter_mut() {
        *w /= z;
    }
    log_w
}

/// Exact per-variable marginals under π.
pub fn exact_marginals(g: &FactorGraph) -> Vec<Vec<f64>> {
    let space = StateSpace::for_graph(g);
    let pi = exact_distribution(g);
    let d = g.domain_size() as usize;
    let mut marg = vec![vec![0.0f64; d]; g.n()];
    for (idx, &p) in pi.iter().enumerate() {
        let s = space.state(idx);
        for (i, &v) in s.iter().enumerate() {
            marg[i][v as usize] += p;
        }
    }
    marg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{models, FactorGraphBuilder};

    #[test]
    fn state_space_roundtrip() {
        let space = StateSpace::new(3, 4);
        assert_eq!(space.len(), 64);
        for idx in 0..space.len() {
            let s = space.state(idx);
            assert_eq!(space.index(&s), idx);
        }
    }

    #[test]
    fn with_value_consistent() {
        let space = StateSpace::new(4, 3);
        for idx in [0usize, 5, 17, 80] {
            for i in 0..4 {
                for u in 0..3 {
                    let j = space.with_value(idx, i, u);
                    let mut s = space.state(idx);
                    s[i] = u as u16;
                    assert_eq!(j, space.index(&s));
                }
            }
        }
    }

    #[test]
    fn exact_distribution_normalizes() {
        let g = models::tiny_random(4, 3, 1.0, 2);
        let pi = exact_distribution(&g);
        let sum: f64 = pi.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(pi.iter().all(|&p| p > 0.0));
    }

    #[test]
    fn exact_distribution_single_pair() {
        // Two vars, one factor w·δ: P(agree) = D e^w / (D e^w + D(D−1)).
        let w = 0.9f64;
        let mut b = FactorGraphBuilder::new(2, 3);
        b.add_potts_pair(0, 1, w);
        let g = b.build();
        let space = StateSpace::for_graph(&g);
        let pi = exact_distribution(&g);
        let agree: f64 = (0..space.len())
            .filter(|&idx| {
                let s = space.state(idx);
                s[0] == s[1]
            })
            .map(|idx| pi[idx])
            .sum();
        let want = 3.0 * w.exp() / (3.0 * w.exp() + 6.0);
        assert!((agree - want).abs() < 1e-12);
    }

    #[test]
    fn exact_marginals_sum_to_one() {
        let g = models::tiny_random(3, 4, 0.8, 3);
        let marg = exact_marginals(&g);
        for row in &marg {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetric_model_uniform_marginals() {
        // A pure Potts model is value-symmetric: every marginal uniform.
        let g = models::tiny_random(4, 3, 1.0, 4);
        let marg = exact_marginals(&g);
        for row in &marg {
            for &p in row {
                assert!((p - 1.0 / 3.0).abs() < 1e-12, "{row:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "too large")]
    fn rejects_huge_space() {
        StateSpace::new(30, 10);
    }
}
