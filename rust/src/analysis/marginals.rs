//! Running marginal estimation — the paper's Figure 1/2 convergence metric.
//!
//! The experiments track a running average of per-variable marginal
//! distributions and report the mean ℓ₂ distance to the known stationary
//! marginals (uniform, by value symmetry of the §B models).

/// Accumulates per-variable value counts over samples and reports
/// marginal-error metrics.
#[derive(Clone, Debug)]
pub struct MarginalEstimator {
    counts: Vec<u64>, // n × d, row-major
    n: usize,
    d: usize,
    samples: u64,
}

impl MarginalEstimator {
    /// For `n` variables over domain size `d`.
    pub fn new(n: usize, d: usize) -> Self {
        Self {
            counts: vec![0; n * d],
            n,
            d,
            samples: 0,
        }
    }

    /// Record one full state sample.
    pub fn update(&mut self, state: &[u16]) {
        debug_assert_eq!(state.len(), self.n);
        for (i, &v) in state.iter().enumerate() {
            self.counts[i * self.d + v as usize] += 1;
        }
        self.samples += 1;
    }

    /// Number of samples recorded.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Current estimate of variable `i`'s marginal.
    pub fn marginal(&self, i: usize) -> Vec<f64> {
        let total = self.samples.max(1) as f64;
        self.counts[i * self.d..(i + 1) * self.d]
            .iter()
            .map(|&c| c as f64 / total)
            .collect()
    }

    /// Mean over variables of ‖p̂_i − uniform‖₂ — the paper's y-axis in
    /// Figures 1 and 2.
    pub fn l2_error_vs_uniform(&self) -> f64 {
        let u = 1.0 / self.d as f64;
        let total = self.samples.max(1) as f64;
        let mut acc = 0.0;
        for i in 0..self.n {
            let mut sq = 0.0;
            for v in 0..self.d {
                let p = self.counts[i * self.d + v] as f64 / total;
                sq += (p - u) * (p - u);
            }
            acc += sq.sqrt();
        }
        acc / self.n as f64
    }

    /// Mean ℓ₂ distance to arbitrary reference marginals (e.g. the exact
    /// ones from enumeration).
    pub fn l2_error_vs(&self, reference: &[Vec<f64>]) -> f64 {
        debug_assert_eq!(reference.len(), self.n);
        let total = self.samples.max(1) as f64;
        let mut acc = 0.0;
        for (i, r) in reference.iter().enumerate() {
            let mut sq = 0.0;
            for (v, &rv) in r.iter().enumerate() {
                let p = self.counts[i * self.d + v] as f64 / total;
                sq += (p - rv) * (p - rv);
            }
            acc += sq.sqrt();
        }
        acc / self.n as f64
    }

    /// Fold another estimator's counts into this one (e.g. pooling
    /// per-chain estimates into a cross-chain aggregate). Panics if the
    /// shapes differ.
    pub fn merge(&mut self, other: &MarginalEstimator) {
        assert_eq!(self.n, other.n, "merge: variable count mismatch");
        assert_eq!(self.d, other.d, "merge: domain size mismatch");
        for (a, &b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.samples += other.samples;
    }

    /// Reset all counts.
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.samples = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marginal_estimates() {
        let mut m = MarginalEstimator::new(2, 3);
        m.update(&[0, 1]);
        m.update(&[0, 2]);
        m.update(&[1, 1]);
        m.update(&[0, 1]);
        assert_eq!(m.samples(), 4);
        let p0 = m.marginal(0);
        assert!((p0[0] - 0.75).abs() < 1e-12);
        assert!((p0[1] - 0.25).abs() < 1e-12);
        assert_eq!(p0[2], 0.0);
    }

    #[test]
    fn error_zero_when_uniform() {
        let mut m = MarginalEstimator::new(1, 2);
        m.update(&[0]);
        m.update(&[1]);
        assert!(m.l2_error_vs_uniform() < 1e-12);
    }

    #[test]
    fn error_max_when_degenerate() {
        // All mass on one value of D=2: ‖(1,0) − (.5,.5)‖₂ = √0.5
        let mut m = MarginalEstimator::new(1, 2);
        for _ in 0..10 {
            m.update(&[0]);
        }
        assert!((m.l2_error_vs_uniform() - 0.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn error_vs_reference() {
        let mut m = MarginalEstimator::new(1, 2);
        m.update(&[0]);
        m.update(&[0]);
        m.update(&[1]);
        let reference = vec![vec![2.0 / 3.0, 1.0 / 3.0]];
        assert!(m.l2_error_vs(&reference) < 1e-12);
    }

    #[test]
    fn merge_pools_counts() {
        let mut a = MarginalEstimator::new(1, 2);
        a.update(&[0]);
        let mut b = MarginalEstimator::new(1, 2);
        b.update(&[1]);
        b.update(&[1]);
        a.merge(&b);
        assert_eq!(a.samples(), 3);
        let p = a.marginal(0);
        assert!((p[0] - 1.0 / 3.0).abs() < 1e-12);
        assert!((p[1] - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut m = MarginalEstimator::new(2, 2);
        m.update(&[1, 1]);
        m.reset();
        assert_eq!(m.samples(), 0);
        assert_eq!(m.marginal(0)[1], 0.0);
    }
}
