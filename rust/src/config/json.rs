//! Minimal recursive-descent JSON parser, used to read
//! `artifacts/manifest.json` written by the AOT compile path.

use std::collections::BTreeMap;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// number (always f64)
    Number(f64),
    /// string
    String(String),
    /// array
    Array(Vec<JsonValue>),
    /// object
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<JsonValue, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// As array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    /// As object.
    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn parse_value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(JsonValue::String(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.parse_lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.parse_lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn parse_number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(JsonValue::Number)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn parse_string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("unknown escape \\{}", esc as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn parse_array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.pos)),
            }
        }
    }

    fn parse_object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.parse_value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let v = JsonValue::parse(
            r#"{
  "jax_version": "0.8.2",
  "n_vars": 400,
  "artifacts": {
    "potts_cond_energies": {
      "file": "potts_cond_energies.hlo.txt",
      "args": [{"shape": [400, 400], "dtype": "float32"}],
      "bytes": 8552
    }
  }
}"#,
        )
        .unwrap();
        assert_eq!(v.get("n_vars").unwrap().as_f64(), Some(400.0));
        let art = v.get("artifacts").unwrap().get("potts_cond_energies").unwrap();
        assert_eq!(
            art.get("file").unwrap().as_str(),
            Some("potts_cond_energies.hlo.txt")
        );
        let shape = art.get("args").unwrap().as_array().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(shape.len(), 2);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("-2.5e2").unwrap().as_f64(), Some(-250.0));
        assert_eq!(
            JsonValue::parse(r#""a\nbA""#).unwrap().as_str(),
            Some("a\nbA")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("1 2").is_err());
        assert!(JsonValue::parse("\"open").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(JsonValue::parse("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(
            JsonValue::parse("{}").unwrap(),
            JsonValue::Object(Default::default())
        );
    }
}
