//! Typed experiment configuration, extracted from a [`TomlDoc`].
//!
//! A config file looks like:
//!
//! ```toml
//! [model]
//! type = "potts_rbf"     # ising_rbf | potts_rbf | ising_grid | potts_random
//! grid_n = 20
//! d = 10
//! beta = 4.6
//! gamma = 1.5
//!
//! [sampler]
//! algorithm = "mgpmh"    # gibbs | min-gibbs | local | mgpmh | doublemin
//! lambda = 25.9          # or lambda_scale = 1.0 (multiples of L² / Ψ²)
//!
//! [run]
//! iters = 1000000
//! chains = 4
//! seed = 42
//! record_every = 1000
//! output_dir = "out"
//!
//! [control]
//! policy = "target-accept"   # off | target-accept | eval-budget
//! target_accept = 0.7
//! band = 0.1
//! adapt_every = 1000
//!
//! [parallel]
//! workers = 4            # 0 = serial random-scan (default)
//!
//! [service]
//! port = 7171            # `mbgibbs serve` listener (0 = ephemeral)
//! pool = 4               # background chains
//! workers = 0            # within-chain workers per pool chain
//! checkpoint_on_shutdown = true
//!
//! [service.adapt]
//! policy = "target-accept"   # pool chains retune λ/B online (docs/SERVICE.md)
//! adapt_every = 1000
//!
//! [service.query_cache]
//! enabled = true         # coalesce + cache conditional queries
//! ttl_ms = 2000
//! capacity = 64
//! ```
//!
//! Model `type = "uai"` loads a factor graph from a UAI MARKOV file via
//! `path = "model.uai"` instead of generating one.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::bench::workload::SamplerSpec;
use crate::control::ControlPolicy;
use crate::graph::models::{self, DenseModel};
use crate::graph::FactorGraph;
use crate::samplers::EnergyPath;

use super::toml::TomlDoc;

/// Model section.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Model family name.
    pub kind: String,
    /// Grid side (rbf/grid models).
    pub grid_n: usize,
    /// Domain size.
    pub d: u16,
    /// Inverse temperature.
    pub beta: f64,
    /// RBF bandwidth γ.
    pub gamma: f64,
    /// Degree (random models).
    pub degree: usize,
    /// Seed (random models).
    pub seed: u64,
    /// Path to a `.uai` file (`type = "uai"` only).
    pub path: Option<PathBuf>,
}

/// Sampler section.
#[derive(Clone, Debug)]
pub struct SamplerConfig {
    /// Algorithm name.
    pub algorithm: String,
    /// Absolute λ (or B for local); if None, `lambda_scale` applies.
    pub lambda: Option<f64>,
    /// λ as a multiple of the algorithm's natural scale (L² or Ψ²).
    pub lambda_scale: f64,
    /// Second batch scale for DoubleMIN (multiple of Ψ²) or absolute.
    pub lambda2: Option<f64>,
    /// Second batch scale factor.
    pub lambda2_scale: f64,
}

/// Run section.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Total iterations per chain.
    pub iters: u64,
    /// Number of parallel chains.
    pub chains: usize,
    /// Master seed.
    pub seed: u64,
    /// Record a marginal-error checkpoint every this many iterations.
    pub record_every: u64,
    /// Output directory for CSVs.
    pub output_dir: PathBuf,
    /// Write a resumable chain checkpoint every this many iterations
    /// (0 = disabled). Files land in `output_dir/checkpoints/`.
    pub checkpoint_every: u64,
    /// Emit a per-chain progress line to stderr every this many
    /// iterations (0 = disabled).
    pub progress_every: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            iters: 1_000_000,
            chains: 1,
            seed: 42,
            record_every: 10_000,
            output_dir: PathBuf::from("out"),
            checkpoint_every: 0,
            progress_every: 0,
        }
    }
}

/// Control section: the adaptive-controller policy.
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// Policy name: `off` | `target-accept` | `eval-budget`.
    pub policy: String,
    /// Acceptance-rate target (target-accept policy).
    pub target_accept: f64,
    /// Half-width of the no-adjustment band around the target.
    pub band: f64,
    /// Review cadence in iterations.
    pub adapt_every: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        Self {
            policy: "off".to_string(),
            target_accept: crate::control::DEFAULT_TARGET_ACCEPT,
            band: crate::control::DEFAULT_BAND,
            adapt_every: crate::control::DEFAULT_ADAPT_EVERY,
        }
    }
}

impl ControlConfig {
    /// Resolve to a validated [`ControlPolicy`].
    pub fn to_policy(&self) -> Result<ControlPolicy> {
        let policy = match ControlPolicy::from_name(&self.policy)? {
            ControlPolicy::Off => ControlPolicy::Off,
            ControlPolicy::TargetAcceptance { .. } => ControlPolicy::TargetAcceptance {
                target: self.target_accept,
                band: self.band,
                adapt_every: self.adapt_every,
            },
            ControlPolicy::EvalBudget { .. } => ControlPolicy::EvalBudget {
                adapt_every: self.adapt_every,
            },
        };
        policy.validate()?;
        Ok(policy)
    }
}

/// Parallel section: within-chain chromatic sweep execution.
#[derive(Clone, Debug, Default)]
pub struct ParallelConfig {
    /// Worker threads per chain. 0 (the default) keeps the serial
    /// random-scan path; ≥ 1 switches to chromatic systematic sweeps
    /// (see `docs/PARALLEL.md`). The CLI `--workers` flag overrides this.
    pub workers: usize,
}

/// Service section: the `mbgibbs serve` daemon (see `docs/SERVICE.md`).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Bind host for the NDJSON/Prometheus listener.
    pub host: String,
    /// Bind port (0 = ephemeral; the bound port is printed on startup).
    pub port: u16,
    /// Number of background chains in the pool.
    pub pool: usize,
    /// Within-chain worker threads per pool chain (0 = serial random
    /// scan; ≥ 1 = chromatic sweeps, parallel-capable samplers only).
    pub workers: usize,
    /// Chains fold local samples into the live estimator every this many
    /// iterations.
    pub publish_every: u64,
    /// Iterations discarded before a chain contributes samples.
    pub burn_in: u64,
    /// Per-chain energy-trace window for live R̂ / pooled-ESS.
    pub window: usize,
    /// Flush v2 chain checkpoints to `run.output_dir/checkpoints/` on
    /// shutdown, enabling bit-exact `--resume`.
    pub checkpoint_on_shutdown: bool,
    /// Default re-burn-in steps for conditional queries.
    pub query_burn_in: u64,
    /// Default estimation steps for conditional queries.
    pub query_samples: u64,
    /// Adaptive-control policy for pool chains (`[service.adapt]`;
    /// independent of the batch `[control]` section). The CLI
    /// `serve --adapt` flags override it.
    pub adapt: ControlConfig,
    /// Conditional-query coalescing/cache knobs
    /// (`[service.query_cache]`).
    pub query_cache: QueryCacheSettings,
}

/// `[service.query_cache]`: the conditional-result cache behind the
/// query engine's request coalescing (see `docs/SERVICE.md`).
#[derive(Clone, Debug)]
pub struct QueryCacheSettings {
    /// Cache completed conditional results (coalescing of in-flight
    /// identical requests stays on either way).
    pub enabled: bool,
    /// Freshness window for cached results, in milliseconds.
    pub ttl_ms: u64,
    /// Maximum distinct evidence keys held at once.
    pub capacity: usize,
}

impl Default for QueryCacheSettings {
    fn default() -> Self {
        Self {
            enabled: true,
            ttl_ms: 2_000,
            capacity: 64,
        }
    }
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".to_string(),
            port: 7171,
            pool: 2,
            workers: 0,
            publish_every: 4_096,
            burn_in: 0,
            window: 4_096,
            checkpoint_on_shutdown: true,
            query_burn_in: 2_000,
            query_samples: 4_000,
            adapt: ControlConfig::default(),
            query_cache: QueryCacheSettings::default(),
        }
    }
}

/// A full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model to build.
    pub model: ModelConfig,
    /// Sampler to run.
    pub sampler: SamplerConfig,
    /// Run parameters.
    pub run: RunConfig,
    /// Adaptive-control parameters.
    pub control: ControlConfig,
    /// Within-chain parallelism.
    pub parallel: ParallelConfig,
    /// Inference-service parameters.
    pub service: ServiceConfig,
}

impl ExperimentConfig {
    /// Load from a TOML file.
    pub fn load(path: &Path) -> Result<Self> {
        let doc = TomlDoc::load(path)?;
        Self::from_doc(&doc).with_context(|| format!("in {}", path.display()))
    }

    /// Extract from a parsed document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let gets = |sec: &str, key: &str| doc.get(sec, key);
        let get_f64 = |sec: &str, key: &str, default: f64| -> Result<f64> {
            match gets(sec, key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow!("{sec}.{key} must be a number")),
            }
        };
        let get_u64 = |sec: &str, key: &str, default: u64| -> Result<u64> {
            match gets(sec, key) {
                None => Ok(default),
                Some(v) => v
                    .as_i64()
                    .filter(|&i| i >= 0)
                    .map(|i| i as u64)
                    .ok_or_else(|| anyhow!("{sec}.{key} must be a non-negative integer")),
            }
        };

        let kind = gets("model", "type")
            .and_then(|v| v.as_str())
            .unwrap_or("potts_rbf")
            .to_string();
        let model = ModelConfig {
            kind,
            grid_n: get_u64("model", "grid_n", 20)? as usize,
            d: get_u64("model", "d", 10)? as u16,
            beta: get_f64("model", "beta", 4.6)?,
            gamma: get_f64("model", "gamma", 1.5)?,
            degree: get_u64("model", "degree", 8)? as usize,
            seed: get_u64("model", "seed", 0)?,
            path: gets("model", "path").and_then(|v| v.as_str()).map(PathBuf::from),
        };
        let sampler = SamplerConfig {
            algorithm: gets("sampler", "algorithm")
                .and_then(|v| v.as_str())
                .unwrap_or("gibbs")
                .to_string(),
            lambda: gets("sampler", "lambda").and_then(|v| v.as_f64()),
            lambda_scale: get_f64("sampler", "lambda_scale", 1.0)?,
            lambda2: gets("sampler", "lambda2").and_then(|v| v.as_f64()),
            lambda2_scale: get_f64("sampler", "lambda2_scale", 1.0)?,
        };
        let run = RunConfig {
            iters: get_u64("run", "iters", 1_000_000)?,
            chains: get_u64("run", "chains", 1)? as usize,
            seed: get_u64("run", "seed", 42)?,
            record_every: get_u64("run", "record_every", 10_000)?,
            output_dir: PathBuf::from(
                gets("run", "output_dir")
                    .and_then(|v| v.as_str())
                    .unwrap_or("out"),
            ),
            checkpoint_every: get_u64("run", "checkpoint_every", 0)?,
            progress_every: get_u64("run", "progress_every", 0)?,
        };
        let control_defaults = ControlConfig::default();
        // `[control]` steers batch runs; `[service.adapt]` steers pool
        // chains — same shape, parsed independently.
        let parse_control = |sec: &str| -> Result<ControlConfig> {
            Ok(ControlConfig {
                policy: gets(sec, "policy")
                    .and_then(|v| v.as_str())
                    .unwrap_or(&control_defaults.policy)
                    .to_string(),
                target_accept: get_f64(sec, "target_accept", control_defaults.target_accept)?,
                band: get_f64(sec, "band", control_defaults.band)?,
                adapt_every: get_u64(sec, "adapt_every", control_defaults.adapt_every)?,
            })
        };
        let control = parse_control("control")?;
        let parallel = ParallelConfig {
            workers: get_u64("parallel", "workers", 0)? as usize,
        };
        let get_bool = |sec: &str, key: &str, default: bool| -> Result<bool> {
            match gets(sec, key) {
                None => Ok(default),
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| anyhow!("{sec}.{key} must be true or false")),
            }
        };
        let sd = ServiceConfig::default();
        let port = get_u64("service", "port", sd.port as u64)?;
        if port > u16::MAX as u64 {
            bail!("service.port must fit in a u16, got {port}");
        }
        let service = ServiceConfig {
            host: gets("service", "host").and_then(|v| v.as_str()).unwrap_or(&sd.host).to_string(),
            port: port as u16,
            pool: get_u64("service", "pool", sd.pool as u64)? as usize,
            workers: get_u64("service", "workers", sd.workers as u64)? as usize,
            publish_every: get_u64("service", "publish_every", sd.publish_every)?,
            burn_in: get_u64("service", "burn_in", sd.burn_in)?,
            window: get_u64("service", "window", sd.window as u64)? as usize,
            checkpoint_on_shutdown: get_bool(
                "service",
                "checkpoint_on_shutdown",
                sd.checkpoint_on_shutdown,
            )?,
            query_burn_in: get_u64("service", "query_burn_in", sd.query_burn_in)?,
            query_samples: get_u64("service", "query_samples", sd.query_samples)?,
            adapt: parse_control("service.adapt")?,
            query_cache: QueryCacheSettings {
                enabled: get_bool("service.query_cache", "enabled", sd.query_cache.enabled)?,
                ttl_ms: get_u64("service.query_cache", "ttl_ms", sd.query_cache.ttl_ms)?,
                capacity: get_u64(
                    "service.query_cache",
                    "capacity",
                    sd.query_cache.capacity as u64,
                )? as usize,
            },
        };
        Ok(Self {
            model,
            sampler,
            run,
            control,
            parallel,
            service,
        })
    }

    /// Build the model. Dense rbf models carry kernel weights for the XLA
    /// backend; others return just the graph.
    pub fn build_model(&self) -> Result<(FactorGraph, Option<DenseModel>)> {
        let m = &self.model;
        Ok(match m.kind.as_str() {
            "ising_rbf" => {
                let dm = models::ising_rbf(m.grid_n, m.beta, m.gamma);
                (dm.graph.clone(), Some(dm))
            }
            "potts_rbf" => {
                let dm = models::potts_rbf(m.grid_n, m.d, m.beta, m.gamma);
                (dm.graph.clone(), Some(dm))
            }
            "ising_grid" => (models::ising_grid_local(m.grid_n, m.beta), None),
            "potts_random" => (
                models::potts_random(m.grid_n * m.grid_n, m.d, m.degree, m.beta, m.seed),
                None,
            ),
            "uai" => {
                let path = m
                    .path
                    .as_ref()
                    .ok_or_else(|| anyhow!("model.type = \"uai\" requires model.path"))?;
                (crate::graph::io::load_uai(path)?, None)
            }
            other => bail!("unknown model type {other:?}"),
        })
    }

    /// Resolve the sampler spec against a built graph (λ scales resolve
    /// to L²/Ψ² multiples).
    pub fn sampler_spec(&self, g: &FactorGraph) -> Result<SamplerSpec> {
        let s = g.stats();
        let (l2, p2) = (s.l * s.l, s.psi * s.psi);
        let sc = &self.sampler;
        Ok(match sc.algorithm.as_str() {
            "gibbs" => SamplerSpec::Gibbs(EnergyPath::Specialized),
            "gibbs-generic" => SamplerSpec::Gibbs(EnergyPath::Generic),
            "min-gibbs" => SamplerSpec::MinGibbs {
                lambda: sc.lambda.unwrap_or(sc.lambda_scale * p2),
            },
            "local" => SamplerSpec::Local {
                batch: sc.lambda.unwrap_or(s.delta as f64 / 4.0).max(1.0) as usize,
            },
            "mgpmh" => SamplerSpec::Mgpmh {
                lambda: sc.lambda.unwrap_or(sc.lambda_scale * l2),
            },
            "doublemin" => SamplerSpec::DoubleMin {
                lambda1: sc.lambda.unwrap_or(sc.lambda_scale * l2),
                lambda2: sc.lambda2.unwrap_or(sc.lambda2_scale * p2),
            },
            other => bail!("unknown sampler algorithm {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> TomlDoc {
        TomlDoc::parse(text).unwrap()
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = ExperimentConfig::from_doc(&doc("")).unwrap();
        assert_eq!(cfg.model.kind, "potts_rbf");
        assert_eq!(cfg.run.iters, 1_000_000);
        assert_eq!(cfg.sampler.algorithm, "gibbs");
        assert_eq!(cfg.control.policy, "off");
        assert!(cfg.control.to_policy().unwrap().is_off());
        assert_eq!(cfg.parallel.workers, 0);
    }

    #[test]
    fn parallel_section_parses() {
        let cfg = ExperimentConfig::from_doc(&doc("[parallel]\nworkers = 4")).unwrap();
        assert_eq!(cfg.parallel.workers, 4);
        assert!(ExperimentConfig::from_doc(&doc("[parallel]\nworkers = -1")).is_err());
    }

    #[test]
    fn control_section_resolves_to_policy() {
        let cfg = ExperimentConfig::from_doc(&doc(
            "[control]\npolicy = \"target-accept\"\ntarget_accept = 0.6\nadapt_every = 500",
        ))
        .unwrap();
        match cfg.control.to_policy().unwrap() {
            ControlPolicy::TargetAcceptance {
                target,
                adapt_every,
                ..
            } => {
                assert_eq!(target, 0.6);
                assert_eq!(adapt_every, 500);
            }
            other => panic!("wrong policy {other:?}"),
        }
    }

    #[test]
    fn control_section_rejects_bad_values() {
        let cfg =
            ExperimentConfig::from_doc(&doc("[control]\npolicy = \"nope\"")).unwrap();
        assert!(cfg.control.to_policy().is_err());
        let cfg = ExperimentConfig::from_doc(&doc(
            "[control]\npolicy = \"target-accept\"\ntarget_accept = 1.5",
        ))
        .unwrap();
        assert!(cfg.control.to_policy().is_err());
    }

    #[test]
    fn full_roundtrip() {
        let cfg = ExperimentConfig::from_doc(&doc(
            r#"
[model]
type = "ising_rbf"
grid_n = 4
beta = 1.0

[sampler]
algorithm = "doublemin"
lambda_scale = 2.0

[run]
iters = 5000
chains = 2
seed = 9
"#,
        ))
        .unwrap();
        let (g, dense) = cfg.build_model().unwrap();
        assert_eq!(g.n(), 16);
        assert!(dense.is_some());
        let spec = cfg.sampler_spec(&g).unwrap();
        match spec {
            SamplerSpec::DoubleMin { lambda1, lambda2 } => {
                let s = g.stats();
                assert!((lambda1 - 2.0 * s.l * s.l).abs() < 1e-9);
                assert!((lambda2 - s.psi * s.psi).abs() < 1e-9);
            }
            _ => panic!("wrong spec"),
        }
    }

    #[test]
    fn service_section_parses() {
        let cfg = ExperimentConfig::from_doc(&doc("")).unwrap();
        assert_eq!(cfg.service.port, 7171);
        assert_eq!(cfg.service.pool, 2);
        assert!(cfg.service.checkpoint_on_shutdown);

        let cfg = ExperimentConfig::from_doc(&doc(
            "[service]\nport = 0\npool = 3\ncheckpoint_on_shutdown = false\nquery_samples = 128",
        ))
        .unwrap();
        assert_eq!(cfg.service.port, 0);
        assert_eq!(cfg.service.pool, 3);
        assert!(!cfg.service.checkpoint_on_shutdown);
        assert_eq!(cfg.service.query_samples, 128);

        assert!(ExperimentConfig::from_doc(&doc("[service]\nport = 70000")).is_err());
        assert!(
            ExperimentConfig::from_doc(&doc("[service]\ncheckpoint_on_shutdown = 3")).is_err()
        );
    }

    #[test]
    fn service_adapt_and_query_cache_parse() {
        let cfg = ExperimentConfig::from_doc(&doc("")).unwrap();
        assert_eq!(cfg.service.adapt.policy, "off");
        assert!(cfg.service.adapt.to_policy().unwrap().is_off());
        assert!(cfg.service.query_cache.enabled);
        assert_eq!(cfg.service.query_cache.ttl_ms, 2_000);
        assert_eq!(cfg.service.query_cache.capacity, 64);

        let cfg = ExperimentConfig::from_doc(&doc(
            "[service.adapt]\npolicy = \"target-accept\"\ntarget_accept = 0.6\nadapt_every = 250\n\
             \n[service.query_cache]\nenabled = false\nttl_ms = 500\ncapacity = 8",
        ))
        .unwrap();
        // `[service.adapt]` is independent of the batch `[control]` section.
        assert!(cfg.control.to_policy().unwrap().is_off());
        match cfg.service.adapt.to_policy().unwrap() {
            ControlPolicy::TargetAcceptance {
                target,
                adapt_every,
                ..
            } => {
                assert_eq!(target, 0.6);
                assert_eq!(adapt_every, 250);
            }
            other => panic!("wrong policy {other:?}"),
        }
        assert!(!cfg.service.query_cache.enabled);
        assert_eq!(cfg.service.query_cache.ttl_ms, 500);
        assert_eq!(cfg.service.query_cache.capacity, 8);

        assert!(ExperimentConfig::from_doc(&doc("[service.query_cache]\nttl_ms = -5")).is_err());
        let cfg =
            ExperimentConfig::from_doc(&doc("[service.adapt]\npolicy = \"nope\"")).unwrap();
        assert!(cfg.service.adapt.to_policy().is_err());
    }

    #[test]
    fn uai_model_loads_from_path() {
        let dir = std::env::temp_dir().join(format!("mbgibbs_cfg_uai_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let g = crate::graph::models::tiny_random(3, 2, 0.5, 5);
        let path = dir.join("m.uai");
        std::fs::write(&path, crate::graph::io::write_uai(&g)).unwrap();
        let toml = format!("[model]\ntype = \"uai\"\npath = \"{}\"", path.display());
        let cfg = ExperimentConfig::from_doc(&doc(&toml)).unwrap();
        let (loaded, dense) = cfg.build_model().unwrap();
        assert_eq!(loaded.n(), 3);
        assert!(dense.is_none());
        // Missing path is a config error, not a panic.
        let cfg = ExperimentConfig::from_doc(&doc("[model]\ntype = \"uai\"")).unwrap();
        assert!(cfg.build_model().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_unknown_model() {
        let cfg = ExperimentConfig::from_doc(&doc("[model]\ntype = \"nope\"")).unwrap();
        assert!(cfg.build_model().is_err());
    }

    #[test]
    fn rejects_unknown_sampler() {
        let cfg =
            ExperimentConfig::from_doc(&doc("[sampler]\nalgorithm = \"nope\"")).unwrap();
        let g = crate::graph::models::tiny_random(3, 2, 1.0, 1);
        assert!(cfg.sampler_spec(&g).is_err());
    }

    #[test]
    fn type_errors_are_reported() {
        let err = ExperimentConfig::from_doc(&doc("[run]\niters = \"many\"")).unwrap_err();
        assert!(err.to_string().contains("run.iters"));
    }
}
