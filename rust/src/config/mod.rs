//! Configuration system.
//!
//! serde is not in the offline dependency set, so parsing is first-party:
//! [`toml`] is a TOML-subset parser for experiment configs, [`json`] a
//! minimal JSON parser for the artifact manifest, and [`schema`] the typed
//! experiment configuration extracted from either.

pub mod json;
pub mod schema;
pub mod toml;

pub use json::JsonValue;
pub use schema::{
    ControlConfig, ExperimentConfig, ModelConfig, ParallelConfig, QueryCacheSettings, RunConfig,
    SamplerConfig, ServiceConfig,
};
pub use toml::{TomlDoc, TomlValue};
