//! Minimal TOML-subset parser: sections, `key = value`, comments.
//!
//! Supported values: strings ("..."), integers, floats, booleans, and flat
//! arrays of those. This covers the experiment configs in `configs/`;
//! anything fancier (nested tables, dates, multiline strings) is rejected
//! with a line-numbered error.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Flat array.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As f64 (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As &str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    /// 1-based line.
    pub line: usize,
    /// Explanation.
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: `sections["section"]["key"]`. Top-level keys live
/// in the "" section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    /// Section name → key → value.
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            let err = |m: &str| TomlError {
                line: lineno + 1,
                message: m.to_string(),
            };
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?;
                if name.contains('[') || name.contains(']') {
                    return Err(err("nested tables are not supported"));
                }
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|m| err(&m))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    /// Get `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
# experiment config
title = "demo"

[model]
type = "potts_rbf"
grid_n = 20
beta = 4.6
d = 10

[run]
iters = 1_000_000
record = true
checkpoints = [10, 100, 1000]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "title").unwrap().as_str(), Some("demo"));
        assert_eq!(doc.get("model", "grid_n").unwrap().as_i64(), Some(20));
        assert_eq!(doc.get("model", "beta").unwrap().as_f64(), Some(4.6));
        assert_eq!(doc.get("run", "iters").unwrap().as_i64(), Some(1_000_000));
        assert_eq!(doc.get("run", "record").unwrap().as_bool(), Some(true));
        match doc.get("run", "checkpoints").unwrap() {
            TomlValue::Array(items) => assert_eq!(items.len(), 3),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn comments_inside_strings() {
        let doc = TomlDoc::parse("s = \"a # b\" # real comment").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(TomlDoc::parse("[[a]]").is_err());
        assert!(TomlDoc::parse("[a.b]").is_ok()); // dotted name treated as flat
    }

    #[test]
    fn value_coercions() {
        assert_eq!(parse_value("3").unwrap().as_f64(), Some(3.0));
        assert_eq!(parse_value("-2.5e1").unwrap().as_f64(), Some(-25.0));
        assert!(parse_value("nope").is_err());
        assert!(parse_value("\"open").is_err());
    }
}
