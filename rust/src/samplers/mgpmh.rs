//! Algorithm 4: MGPMH — Minibatch-Gibbs-Proposal Metropolis–Hastings.
//!
//! Uses a local Poisson-weighted minibatch proposal (importance-weighted
//! version of Algorithm 3) and corrects it with an exact local
//! Metropolis–Hastings acceptance test. Reversible with stationary
//! distribution exactly π (Theorem 3); spectral gap ≥ exp(−L²/λ)·γ_Gibbs
//! (Theorem 4), so λ = Θ(L²) gives an O(1) convergence penalty at
//! per-iteration cost O(DL² + Δ).

use std::sync::Arc;

use crate::graph::FactorGraph;
use crate::metrics::SamplerMetrics;
use crate::rng::{sample_categorical_from_energies, Rng, SparsePoissonSampler};

use super::{local_proposal_tables, Hyperparams, Sampler, StepStats};

/// MGPMH sampler (paper Algorithm 4).
pub struct MgpmhSampler<'g> {
    graph: &'g FactorGraph,
    lambda: f64,
    /// Per-variable sparse Poisson samplers over A[i] with rates λM_φ/L.
    per_var: Vec<SparsePoissonSampler>,
    /// Per-variable importance weights L/(λ M_φ) aligned with A[i].
    weights: Vec<Vec<f64>>,
    /// Scratch: (factor id, s_φ · L/(λ M_φ)) for the drawn minibatch.
    batch: Vec<(u32, f64)>,
    eps: Vec<f64>,
    exact: Vec<f64>,
    accepted: u64,
    proposed: u64,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'g> MgpmhSampler<'g> {
    /// Create with expected first-minibatch size λ (paper recipe: λ = L²).
    pub fn new(graph: &'g FactorGraph, lambda: f64) -> Self {
        let (per_var, weights) = local_proposal_tables(graph, lambda);
        Self {
            graph,
            lambda,
            per_var,
            weights,
            batch: Vec::new(),
            eps: vec![0.0; graph.domain_size() as usize],
            exact: vec![0.0; graph.domain_size() as usize],
            accepted: 0,
            proposed: 0,
            metrics: None,
        }
    }

    /// Expected minibatch size λ.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Retune λ: rebuilds the per-variable Poisson proposal tables.
    pub fn set_lambda(&mut self, lambda: f64) {
        let (per_var, weights) = local_proposal_tables(self.graph, lambda);
        self.per_var = per_var;
        self.weights = weights;
        self.lambda = lambda;
    }

    /// Empirical acceptance rate so far.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

impl Sampler for MgpmhSampler<'_> {
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let g = self.graph;
        let d = g.domain_size() as usize;
        let cur = state[i] as usize;
        let factors = g.factors_of(i);
        let mut evals = 0u64;

        // Draw the sparse minibatch s_φ ~ Poisson(λ M_φ / L) over A[i]
        // in O(λ) expected time.
        let batch = &mut self.batch;
        batch.clear();
        let wts = &self.weights[i];
        self.per_var[i].sample_into(rng, |pos, s| {
            batch.push((factors[pos], s as f64 * wts[pos]));
        });

        // ε_u = Σ_{φ∈S} (s_φ L / λ M_φ) φ(x_{i→u}) for all u: O(D·|S|).
        let saved = state[i];
        for u in 0..d {
            state[i] = u as u16;
            let mut sum = 0.0;
            for &(fid, w) in batch.iter() {
                sum += w * g.value(fid as usize, state);
            }
            self.eps[u] = sum;
        }
        state[i] = saved;
        let batch_size = batch.len() as u64;
        evals += d as u64 * batch_size;

        // Propose v ~ ψ(v) ∝ exp(ε_v).
        let v = sample_categorical_from_energies(rng, &self.eps);
        self.proposed += 1;
        if v == cur {
            // y = x: a = 1 (numerator and denominator coincide).
            self.accepted += 1;
            if let Some(m) = &self.metrics {
                m.steps.add(1);
                m.factor_evals.add(evals);
                m.minibatch_local.record(batch_size);
                m.proposals.add(1);
                m.accepts.add(1);
            }
            return StepStats {
                variable: i,
                factor_evals: evals,
                accepted: true,
            };
        }

        // Exact local energies for the acceptance test: the structure-
        // aware O(Δ + D) path computes the whole exact conditional table,
        // from which both Σφ(x) = ε*_{x(i)} and Σφ(y) = ε*_{y(i)} read
        // off directly (§Perf: ~2× over the per-factor double loop).
        g.cond_energies_fast(state, i, &mut self.exact);
        let local_x = self.exact[cur];
        let local_y = self.exact[v];
        evals += factors.len() as u64;

        let log_a = (local_y - local_x) + (self.eps[cur] - self.eps[v]);
        let accept = log_a >= 0.0 || rng.f64() < log_a.exp();
        if accept {
            state[i] = v as u16;
            self.accepted += 1;
        }
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add(evals);
            m.minibatch_local.record(batch_size);
            m.proposals.add(1);
            m.accepts.add(accept as u64);
        }
        StepStats {
            variable: i,
            factor_evals: evals,
            accepted: accept,
        }
    }

    fn is_site_local(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "mgpmh"
    }

    fn hyperparams(&self) -> Hyperparams {
        Hyperparams::with_lambda(self.lambda)
    }

    fn set_hyperparams(&mut self, hp: &Hyperparams) -> bool {
        match hp.lambda {
            Some(l) if l > 0.0 && l != self.lambda => {
                self.set_lambda(l);
                true
            }
            _ => false,
        }
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;
    use crate::samplers::test_support::{empirical_marginals, marginal_error_vs_exact};

    /// Theorem 3: stationary distribution is exactly π.
    #[test]
    fn stationary_is_pi() {
        let g = models::tiny_random(3, 3, 0.7, 61);
        let l = g.stats().l;
        let mut s = MgpmhSampler::new(&g, (l * l).max(2.0));
        let m = empirical_marginals(&g, &mut s, 400_000, 40_000, 62);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.015, "err = {err}");
    }

    /// Even a tiny λ (slow mixing, low acceptance) must stay unbiased.
    #[test]
    fn unbiased_with_tiny_lambda() {
        let g = models::tiny_random(3, 2, 0.5, 63);
        let mut s = MgpmhSampler::new(&g, 0.5);
        let m = empirical_marginals(&g, &mut s, 800_000, 80_000, 64);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.025, "err = {err}");
    }

    /// With λ large the proposal approaches the exact conditional and the
    /// acceptance rate must go to ~1 (Theorem 4 in the λ → ∞ limit).
    #[test]
    fn acceptance_approaches_one_with_large_lambda() {
        let g = models::tiny_random(4, 3, 0.6, 65);
        let mut s = MgpmhSampler::new(&g, 500.0);
        let mut rng = Pcg64::seeded(66);
        let mut state = vec![0u16; 4];
        for _ in 0..20_000 {
            s.step(&mut state, &mut rng);
        }
        assert!(
            s.acceptance_rate() > 0.97,
            "acceptance = {}",
            s.acceptance_rate()
        );
    }

    /// Acceptance rate is monotone-ish in λ: smaller λ, more rejections.
    #[test]
    fn acceptance_degrades_with_small_lambda() {
        let g = models::tiny_random(4, 3, 1.0, 67);
        let mut rates = Vec::new();
        for &lam in &[0.5f64, 5.0, 50.0] {
            let mut s = MgpmhSampler::new(&g, lam);
            let mut rng = Pcg64::seeded(68);
            let mut state = vec![0u16; 4];
            for _ in 0..30_000 {
                s.step(&mut state, &mut rng);
            }
            rates.push(s.acceptance_rate());
        }
        assert!(rates[0] < rates[1] && rates[1] < rates[2], "{rates:?}");
    }

    /// Expected per-step work: ~ D·λ·(L_i/L averaged) + 2Δ evals; on the
    /// table1 workload all L_i = L so E[|S|] = λ exactly.
    #[test]
    fn cost_model_table1_workload() {
        let n = 40;
        let d = 5usize;
        let g = models::table1_workload(n, d as u16, 2.0);
        let lambda = 6.0;
        let mut s = MgpmhSampler::new(&g, lambda);
        let mut rng = Pcg64::seeded(69);
        let mut state = vec![0u16; n];
        let trials = 30_000;
        let mut total = 0u64;
        let mut accepted_moves = 0u64;
        for _ in 0..trials {
            let st = s.step(&mut state, &mut rng);
            total += st.factor_evals;
            accepted_moves += (st.accepted && st.variable < n) as u64;
        }
        let mean = total as f64 / trials as f64;
        // D·E[|S|] + 2Δ·P(v != cur); bound loosely from both sides.
        let upper = d as f64 * lambda + 2.0 * (n - 1) as f64 + 1.0;
        assert!(mean < upper, "mean evals {mean} > {upper}");
        assert!(mean > d as f64 * lambda * 0.5, "mean evals {mean} too low");
        assert!(accepted_moves > 0);
    }
}
