//! Algorithm 3: Local Minibatch Gibbs.
//!
//! One minibatch S ⊂ A[i] of size B per iteration, shared by all D
//! conditional energies: ε_u = (|A[i]|/B) Σ_{φ∈S} φ(x_{i→u}). Runs in
//! O(BD) — but there is no reversibility argument, so (as the paper
//! stresses) there are *no guarantees* on what it converges to. It is the
//! proposal inside MGPMH and the empirical subject of Figure 2(a).

use std::sync::Arc;

use crate::graph::FactorGraph;
use crate::metrics::SamplerMetrics;
use crate::rng::{sample_categorical_from_energies, Rng};

use super::{Hyperparams, Sampler, StepStats};

/// Local Minibatch Gibbs sampler (paper Algorithm 3).
pub struct LocalMinibatchSampler<'g> {
    graph: &'g FactorGraph,
    batch: usize,
    eps: Vec<f64>,
    picked: Vec<u32>,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'g> LocalMinibatchSampler<'g> {
    /// Create with per-iteration minibatch size `batch` (B in the paper).
    /// B is clamped to |A[i]| per variable at sampling time.
    pub fn new(graph: &'g FactorGraph, batch: usize) -> Self {
        assert!(batch > 0, "batch size must be positive");
        Self {
            graph,
            batch,
            eps: vec![0.0; graph.domain_size() as usize],
            picked: Vec::with_capacity(batch),
            metrics: None,
        }
    }

    /// Configured minibatch size B.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Uniform sample of `b` distinct positions in [0, m) into `self.picked`.
    /// O(b) expected via rejection while b ≤ m/2, else Floyd's algorithm.
    fn sample_positions(&mut self, m: usize, b: usize, rng: &mut dyn Rng) {
        self.picked.clear();
        if b >= m {
            self.picked.extend(0..m as u32);
            return;
        }
        // Floyd's algorithm: exactly b distinct values, O(b) draws.
        for j in (m - b)..m {
            let t = rng.index(j + 1) as u32;
            if self.picked.contains(&t) {
                self.picked.push(j as u32);
            } else {
                self.picked.push(t);
            }
        }
    }
}

impl Sampler for LocalMinibatchSampler<'_> {
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let g = self.graph;
        let d = g.domain_size() as usize;
        let deg = g.degree(i);
        let b = self.batch.min(deg);
        self.sample_positions(deg, b, rng);

        let scale = deg as f64 / b as f64;
        let saved = state[i];
        let factors = g.factors_of(i);
        for u in 0..d {
            state[i] = u as u16;
            let mut sum = 0.0;
            for &pos in &self.picked {
                sum += g.value(factors[pos as usize] as usize, state);
            }
            self.eps[u] = scale * sum;
        }
        state[i] = saved;

        let v = sample_categorical_from_energies(rng, &self.eps);
        state[i] = v as u16;
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add((b * d) as u64);
            m.minibatch_local.record(b as u64);
        }
        StepStats {
            variable: i,
            factor_evals: (b * d) as u64,
            accepted: true,
        }
    }

    fn is_site_local(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "local-minibatch"
    }

    fn hyperparams(&self) -> Hyperparams {
        Hyperparams::with_batch(self.batch)
    }

    fn set_hyperparams(&mut self, hp: &Hyperparams) -> bool {
        match hp.batch {
            Some(b) if b >= 1 && b != self.batch => {
                self.batch = b;
                true
            }
            _ => false,
        }
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;
    use crate::samplers::test_support::{empirical_marginals, marginal_error_vs_exact};
    use crate::samplers::{EnergyPath, GibbsSampler};

    /// With B = Δ the sampler IS vanilla Gibbs (scale = 1, full batch).
    #[test]
    fn full_batch_equals_gibbs() {
        let g = models::tiny_random(3, 3, 0.8, 31);
        let delta = g.stats().delta;
        let mut a = LocalMinibatchSampler::new(&g, delta);
        let mut b = GibbsSampler::new(&g, EnergyPath::Generic);
        let ma = empirical_marginals(&g, &mut a, 200_000, 20_000, 32);
        let mb = empirical_marginals(&g, &mut b, 200_000, 20_000, 33);
        for (ra, rb) in ma.iter().zip(mb.iter()) {
            for (x, y) in ra.iter().zip(rb.iter()) {
                assert!((x - y).abs() < 0.02, "{x} vs {y}");
            }
        }
    }

    /// Figure 2(a) behavior: small batches still track Gibbs closely on
    /// the paper-style models (empirically near-unbiased).
    #[test]
    fn small_batch_close_to_exact() {
        let g = models::tiny_random(3, 2, 0.5, 34);
        let mut s = LocalMinibatchSampler::new(&g, 1);
        let m = empirical_marginals(&g, &mut s, 400_000, 40_000, 35);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.1, "err = {err}");
    }

    /// Distinct-position sampling: all picked positions valid + distinct.
    #[test]
    fn positions_distinct_and_in_range() {
        let g = models::tiny_random(4, 2, 0.5, 36);
        let mut s = LocalMinibatchSampler::new(&g, 2);
        let mut rng = Pcg64::seeded(37);
        for _ in 0..2000 {
            s.sample_positions(5, 3, &mut rng);
            assert_eq!(s.picked.len(), 3);
            let mut seen = std::collections::HashSet::new();
            for &p in &s.picked {
                assert!(p < 5);
                assert!(seen.insert(p), "duplicate position {p}");
            }
        }
    }

    /// Floyd sampling must be uniform over subsets: each position appears
    /// with probability b/m.
    #[test]
    fn positions_uniform() {
        let g = models::tiny_random(4, 2, 0.5, 38);
        let mut s = LocalMinibatchSampler::new(&g, 2);
        let mut rng = Pcg64::seeded(39);
        let (m, b) = (6usize, 2usize);
        let mut counts = vec![0u64; m];
        let trials = 120_000;
        for _ in 0..trials {
            s.sample_positions(m, b, &mut rng);
            for &p in &s.picked {
                counts[p as usize] += 1;
            }
        }
        let want = b as f64 / m as f64;
        for (p, &c) in counts.iter().enumerate() {
            let f = c as f64 / trials as f64;
            assert!((f - want).abs() < 0.01, "pos {p}: {f} vs {want}");
        }
    }

    /// Cost accounting: B·D factor evaluations per step.
    #[test]
    fn cost_is_bd() {
        let g = models::table1_workload(30, 5, 2.0);
        let mut s = LocalMinibatchSampler::new(&g, 8);
        let mut rng = Pcg64::seeded(40);
        let mut state = vec![0u16; 30];
        let st = s.step(&mut state, &mut rng);
        assert_eq!(st.factor_evals, 8 * 5);
    }
}
