//! Algorithm 1: vanilla Gibbs sampling — the exact baseline.

use std::sync::Arc;

use crate::graph::FactorGraph;
use crate::metrics::SamplerMetrics;
use crate::rng::{sample_categorical_from_energies, Rng};

use super::{EnergyPath, Sampler, StepStats};

/// Variable-selection order (He et al. [4] show it can matter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanOrder {
    /// Pick i uniformly at random each step (the paper's Algorithm 1).
    Random,
    /// Sweep variables 0, 1, …, n−1 cyclically ("systematic scan").
    Systematic,
}

/// Vanilla single-site Gibbs sampler (paper Algorithm 1).
///
/// Each step resamples a variable i from its exact conditional
/// distribution ρ(v) ∝ exp(ε_v), ε_v = Σ_{φ∈A[i]} φ(x_{i→v}).
pub struct GibbsSampler<'g> {
    graph: &'g FactorGraph,
    path: EnergyPath,
    scan: ScanOrder,
    cursor: usize,
    eps: Vec<f64>,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'g> GibbsSampler<'g> {
    /// Create a random-scan sampler; `path` selects the O(DΔ) generic
    /// evaluation loop (the paper's cost model) or the O(Δ + D)
    /// specialized path.
    pub fn new(graph: &'g FactorGraph, path: EnergyPath) -> Self {
        Self::with_scan(graph, path, ScanOrder::Random)
    }

    /// Create with an explicit scan order.
    pub fn with_scan(graph: &'g FactorGraph, path: EnergyPath, scan: ScanOrder) -> Self {
        Self {
            graph,
            path,
            scan,
            cursor: 0,
            eps: vec![0.0; graph.domain_size() as usize],
            metrics: None,
        }
    }

    /// The evaluation path in use.
    pub fn path(&self) -> EnergyPath {
        self.path
    }

    /// The scan order in use.
    pub fn scan(&self) -> ScanOrder {
        self.scan
    }
}

impl Sampler for GibbsSampler<'_> {
    fn select_site(&mut self, state: &[u16], rng: &mut dyn Rng) -> usize {
        match self.scan {
            ScanOrder::Random => rng.index(state.len()),
            ScanOrder::Systematic => {
                let i = self.cursor;
                self.cursor = (self.cursor + 1) % self.graph.n();
                i
            }
        }
    }

    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let g = self.graph;
        let d = g.domain_size() as u64;
        let evals = match self.path {
            EnergyPath::Generic => {
                g.cond_energies_generic(state, i, &mut self.eps);
                d * g.degree(i) as u64
            }
            EnergyPath::Specialized => {
                g.cond_energies_fast(state, i, &mut self.eps);
                g.degree(i) as u64
            }
        };
        let v = sample_categorical_from_energies(rng, &self.eps);
        state[i] = v as u16;
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add(evals);
        }
        StepStats {
            variable: i,
            factor_evals: evals,
            accepted: true,
        }
    }

    fn is_site_local(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "gibbs"
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::graph::models;
    use crate::graph::FactorGraphBuilder;
    use crate::rng::Pcg64;
    use crate::samplers::test_support::{empirical_marginals, marginal_error_vs_exact};

    #[test]
    fn converges_to_exact_marginals() {
        let g = models::tiny_random(3, 2, 1.0, 3);
        let mut s = GibbsSampler::new(&g, EnergyPath::Generic);
        let m = empirical_marginals(&g, &mut s, 300_000, 30_000, 11);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.01, "err = {err}");
    }

    #[test]
    fn both_paths_same_distribution() {
        let g = models::tiny_random(3, 3, 0.7, 5);
        let mut a = GibbsSampler::new(&g, EnergyPath::Generic);
        let mut b = GibbsSampler::new(&g, EnergyPath::Specialized);
        // Same seed -> identical trajectories (paths compute identical
        // energies, so the categorical draws consume the same randomness).
        let ma = empirical_marginals(&g, &mut a, 50_000, 0, 13);
        let mb = empirical_marginals(&g, &mut b, 50_000, 0, 13);
        for (ra, rb) in ma.iter().zip(mb.iter()) {
            for (x, y) in ra.iter().zip(rb.iter()) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn factor_evals_match_cost_model() {
        let g = models::table1_workload(20, 4, 2.0); // Δ = 19, D = 4
        let mut rng = Pcg64::seeded(1);
        let mut state = vec![0u16; 20];
        let mut s = GibbsSampler::new(&g, EnergyPath::Generic);
        let st = s.step(&mut state, &mut rng);
        assert_eq!(st.factor_evals, 4 * 19);
        let mut s = GibbsSampler::new(&g, EnergyPath::Specialized);
        let st = s.step(&mut state, &mut rng);
        assert_eq!(st.factor_evals, 19);
    }

    #[test]
    fn systematic_scan_covers_all_variables() {
        let g = models::tiny_random(5, 2, 0.5, 8);
        let mut s = GibbsSampler::with_scan(&g, EnergyPath::Specialized, ScanOrder::Systematic);
        let mut rng = Pcg64::seeded(9);
        let mut state = vec![0u16; 5];
        let mut seen = vec![false; 5];
        for _ in 0..5 {
            let st = s.step(&mut state, &mut rng);
            seen[st.variable] = true;
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn systematic_scan_converges_to_pi() {
        let g = models::tiny_random(3, 2, 0.8, 10);
        let mut s = GibbsSampler::with_scan(&g, EnergyPath::Specialized, ScanOrder::Systematic);
        let m = empirical_marginals(&g, &mut s, 300_000, 30_000, 12);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.01, "err = {err}");
    }

    #[test]
    fn respects_conditional_distribution_two_vars() {
        // One pair factor w*delta: P(x0 = x1) = e^w / (e^w + (D-1)).
        let w = 1.2f64;
        let mut b = FactorGraphBuilder::new(2, 3);
        b.add_potts_pair(0, 1, w);
        let g = b.build();
        let pi = analysis::exact_distribution(&g);
        let space = analysis::StateSpace::new(2, 3);
        let mut agree = 0.0;
        for idx in 0..space.len() {
            let st = space.state(idx);
            if st[0] == st[1] {
                agree += pi[idx];
            }
        }
        let want = 3.0 * w.exp() / (3.0 * w.exp() + 6.0);
        assert!((agree - want).abs() < 1e-12);

        // Now empirically via the sampler.
        let mut s = GibbsSampler::new(&g, EnergyPath::Generic);
        let mut rng = Pcg64::seeded(2);
        let mut state = vec![0u16; 2];
        let mut hits = 0u64;
        let iters = 200_000;
        for _ in 0..iters {
            s.step(&mut state, &mut rng);
            hits += (state[0] == state[1]) as u64;
        }
        let frac = hits as f64 / iters as f64;
        assert!((frac - want).abs() < 0.01, "frac={frac} want={want}");
    }
}
