//! Energy estimators μ_x for the minibatched samplers.
//!
//! [`PoissonEnergyEstimator`] is the paper's Eq. (2): draw s_φ ~
//! Poisson(λ M_φ / Ψ) via the sparse O(λ) sampler and return
//!
//! ```text
//! ε_x = Σ_{φ: s_φ>0} s_φ · log(1 + Ψ φ(x) / (λ M_φ))
//! ```
//!
//! Lemma 1: E[exp(ε_x)] = exp(ζ(x)) — the *bias-adjusted* estimator that
//! makes MIN-Gibbs and DoubleMIN-Gibbs exactly unbiased (Theorem 1/5).
//!
//! [`FixedBatchEstimator`] is the naive Horvitz–Thompson scheme
//! ε_x = (|Φ|/B) Σ_{φ∈S} φ(x): simpler, but E[exp(ε_x)] ≠ exp(ζ(x)), so
//! chains built on it are biased (tempered); it exists as the ablation
//! baseline the paper's §2 discussion contrasts against.

use crate::graph::FactorGraph;
use crate::rng::{Rng, SparsePoissonSampler};

/// The Eq. (2) bias-adjusted Poisson minibatch estimator.
pub struct PoissonEnergyEstimator {
    sparse: SparsePoissonSampler,
    /// Per-factor log-argument coefficient Ψ / (λ M_φ).
    coef: Vec<f64>,
    /// Precomputed log(1 + Ψ/λ) contribution for φ(x) = M_φ — since
    /// coef·M_φ = Ψ/λ for every factor, two-valued factors (Potts/Ising
    /// pairs take only 0 or M_φ) skip the `ln_1p` in the hot loop.
    log1p_at_max: f64,
    max_energies: Vec<f64>,
    lambda: f64,
    psi: f64,
}

impl PoissonEnergyEstimator {
    /// Build for `graph` with expected batch size λ (paper: λ = Θ(Ψ²)
    /// for an O(1) spectral-gap penalty, Lemma 2).
    pub fn new(graph: &FactorGraph, lambda: f64) -> Self {
        assert!(lambda > 0.0, "λ must be positive");
        let psi = graph.stats().psi;
        let rates: Vec<f64> = graph
            .max_energies()
            .iter()
            .map(|&m| lambda * m / psi)
            .collect();
        let coef: Vec<f64> = graph
            .max_energies()
            .iter()
            .map(|&m| if m > 0.0 { psi / (lambda * m) } else { 0.0 })
            .collect();
        Self {
            sparse: SparsePoissonSampler::new(&rates),
            coef,
            log1p_at_max: (psi / lambda).ln_1p(),
            max_energies: graph.max_energies().to_vec(),
            lambda,
            psi,
        }
    }

    /// Expected minibatch size λ.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Total maximum energy Ψ of the graph this estimator was built for.
    pub fn psi(&self) -> f64 {
        self.psi
    }

    /// Draw ε_x ~ μ_x. Returns `(estimate, factor_evals)`; cost is O(λ)
    /// expected (the sparse Poisson-vector trick, §3 footnote 7).
    pub fn estimate(
        &mut self,
        graph: &FactorGraph,
        state: &[u16],
        rng: &mut dyn Rng,
    ) -> (f64, u64) {
        let coef = &self.coef;
        let log1p_at_max = self.log1p_at_max;
        let max_energies = &self.max_energies;
        let mut eps = 0.0f64;
        let mut evals = 0u64;
        // Trial-level iteration: Eq. (2) is linear in s_φ, so per-trial
        // accumulation is exact and skips the dedup scratch (§Perf).
        self.sparse.sample_trials(rng, |fid, s| {
            let phi = graph.value(fid, state);
            evals += s as u64;
            // Fast paths: φ = 0 contributes nothing; φ = M_φ has the
            // factor-independent precomputed log (covers Potts/Ising).
            if phi == 0.0 {
                return;
            }
            eps += if phi == max_energies[fid] {
                s as f64 * log1p_at_max
            } else {
                s as f64 * (coef[fid] * phi).ln_1p()
            };
        });
        (eps, evals)
    }
}

/// Naive fixed-size minibatch estimator (uniform with replacement):
/// ε_x = (|Φ|/B) Σ_{φ∈S} φ(x). Biased in exp — ablation baseline only.
pub struct FixedBatchEstimator {
    batch: usize,
}

impl FixedBatchEstimator {
    /// Estimator drawing `batch` factors uniformly with replacement.
    pub fn new(batch: usize) -> Self {
        assert!(batch > 0);
        Self { batch }
    }

    /// Draw ε_x. Returns `(estimate, factor_evals)`.
    pub fn estimate(
        &self,
        graph: &FactorGraph,
        state: &[u16],
        rng: &mut dyn Rng,
    ) -> (f64, u64) {
        let m = graph.num_factors();
        let scale = m as f64 / self.batch as f64;
        let mut sum = 0.0;
        for _ in 0..self.batch {
            let fid = rng.index(m);
            sum += graph.value(fid, state);
        }
        (scale * sum, self.batch as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;

    /// Lemma 1, tested by Monte Carlo: E[exp(ε_x)] = exp(ζ(x)).
    #[test]
    fn eq2_unbiased_in_exp() {
        let g = models::tiny_random(4, 3, 0.4, 9);
        let mut est = PoissonEnergyEstimator::new(&g, 25.0);
        let mut rng = Pcg64::seeded(50);
        let state: Vec<u16> = vec![0, 1, 2, 1];
        let zeta = g.total_energy(&state);
        let trials = 400_000;
        let mut acc = 0.0f64;
        for _ in 0..trials {
            let (eps, _) = est.estimate(&g, &state, &mut rng);
            acc += eps.exp();
        }
        let mean = acc / trials as f64;
        let want = zeta.exp();
        assert!(
            (mean - want).abs() / want < 0.02,
            "E[exp ε] = {mean}, exp(ζ) = {want}"
        );
    }

    /// Jensen: the raw estimate underestimates ζ(x) in expectation
    /// (proof of Lemma 2), and E[ε_x] ≥ ζ(x) − Ψ²/λ.
    #[test]
    fn eq2_mean_bounds() {
        let g = models::tiny_random(4, 2, 0.5, 10);
        let psi = g.stats().psi;
        let lambda = 40.0;
        let mut est = PoissonEnergyEstimator::new(&g, lambda);
        let mut rng = Pcg64::seeded(51);
        let state: Vec<u16> = vec![1, 0, 1, 0];
        let zeta = g.total_energy(&state);
        let trials = 200_000;
        let mean: f64 = (0..trials)
            .map(|_| est.estimate(&g, &state, &mut rng).0)
            .sum::<f64>()
            / trials as f64;
        assert!(mean <= zeta + 0.01, "mean {mean} > ζ {zeta}");
        assert!(
            mean >= zeta - psi * psi / lambda - 0.01,
            "mean {mean} below Lemma-2 lower bound"
        );
    }

    /// Lemma 2 concentration: with λ ≥ max(8Ψ²/δ² log(2/a), 2Ψ²/δ),
    /// P(|ε_x − ζ(x)| ≥ δ) ≤ a.
    #[test]
    fn eq2_concentration_lemma2() {
        let g = models::tiny_random(5, 2, 0.3, 11);
        let psi = g.stats().psi;
        let delta = 0.5f64;
        let a = 0.05f64;
        let lambda = (8.0 * psi * psi / (delta * delta) * (2.0 / a).ln())
            .max(2.0 * psi * psi / delta);
        let mut est = PoissonEnergyEstimator::new(&g, lambda);
        let mut rng = Pcg64::seeded(52);
        let state: Vec<u16> = vec![0, 0, 1, 1, 0];
        let zeta = g.total_energy(&state);
        let trials = 20_000;
        let bad = (0..trials)
            .filter(|_| {
                let (eps, _) = est.estimate(&g, &state, &mut rng);
                (eps - zeta).abs() >= delta
            })
            .count();
        let frac = bad as f64 / trials as f64;
        assert!(frac <= a, "violation rate {frac} > {a}");
    }

    /// Expected work is λ factor evaluations per draw.
    #[test]
    fn expected_cost_is_lambda() {
        let g = models::tiny_random(6, 2, 0.5, 12);
        let lambda = 15.0;
        let mut est = PoissonEnergyEstimator::new(&g, lambda);
        let mut rng = Pcg64::seeded(53);
        let state: Vec<u16> = vec![0; 6];
        let trials = 50_000;
        let total: u64 = (0..trials)
            .map(|_| est.estimate(&g, &state, &mut rng).1)
            .sum();
        let mean = total as f64 / trials as f64;
        // Touched factors ≤ B (collisions merge), so mean ≤ λ and near it.
        assert!(mean <= lambda + 0.5, "mean evals {mean}");
        assert!(mean > lambda * 0.5, "mean evals {mean} suspiciously low");
    }

    /// The fixed-batch estimator is unbiased in ε but NOT in exp(ε):
    /// E[exp(ε)] > exp(ζ) by Jensen — the bias MIN-Gibbs would inherit.
    #[test]
    fn fixed_batch_biased_in_exp() {
        let g = models::tiny_random(4, 2, 0.8, 13);
        let est = FixedBatchEstimator::new(2);
        let mut rng = Pcg64::seeded(54);
        let state: Vec<u16> = vec![0, 1, 0, 1];
        let zeta = g.total_energy(&state);
        let trials = 300_000;
        let (mut mean_eps, mut mean_exp) = (0.0, 0.0);
        for _ in 0..trials {
            let (e, _) = est.estimate(&g, &state, &mut rng);
            mean_eps += e;
            mean_exp += e.exp();
        }
        mean_eps /= trials as f64;
        mean_exp /= trials as f64;
        assert!((mean_eps - zeta).abs() < 0.02, "ε mean {mean_eps} vs ζ {zeta}");
        // strictly biased upward in exp (Jensen gap visible at B=2)
        assert!(mean_exp > zeta.exp() * 1.01, "exp mean {mean_exp}");
    }
}
