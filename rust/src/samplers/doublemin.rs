//! Algorithm 5: DoubleMIN-Gibbs — doubly minibatched Gibbs.
//!
//! MGPMH's proposal (first minibatch, local, λ₁ = Θ(L²)) combined with a
//! *second* global Eq. (2) minibatch estimate (λ₂ = Θ(Ψ²)) replacing the
//! exact local energies in the acceptance test. The chain lives on the
//! augmented space Ω × ℝ, caching the current state's estimate ξ_x.
//! Same stationary distribution as MIN-Gibbs — exactly π in the x-marginal
//! with the bias-adjusted estimator (Theorem 5) — and spectral gap within
//! exp(−4δ) of MGPMH (Theorem 6). Total cost O(DL² + Ψ²): independent of
//! both the degree Δ (acceptance) and D·Δ (proposal).

use std::sync::Arc;

use crate::graph::FactorGraph;
use crate::metrics::SamplerMetrics;
use crate::rng::{sample_categorical_from_energies, Rng, SparsePoissonSampler};

use super::{estimator::PoissonEnergyEstimator, local_proposal_tables, Hyperparams, Sampler, StepStats};

/// DoubleMIN-Gibbs sampler (paper Algorithm 5).
pub struct DoubleMinGibbsSampler<'g> {
    graph: &'g FactorGraph,
    lambda1: f64,
    /// First (local, MGPMH) minibatch machinery.
    per_var: Vec<SparsePoissonSampler>,
    weights: Vec<Vec<f64>>,
    batch: Vec<(u32, f64)>,
    eps: Vec<f64>,
    /// Second (global, Eq. 2) minibatch estimator and the cached ξ_x.
    estimator: PoissonEnergyEstimator,
    cached_xi: Option<f64>,
    accepted: u64,
    proposed: u64,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'g> DoubleMinGibbsSampler<'g> {
    /// Create with first-batch size λ₁ (paper: Θ(L²)) and second-batch
    /// size λ₂ (paper: Θ(Ψ²)).
    pub fn new(graph: &'g FactorGraph, lambda1: f64, lambda2: f64) -> Self {
        assert!(lambda2 > 0.0, "batch sizes must be positive");
        let (per_var, weights) = local_proposal_tables(graph, lambda1);
        Self {
            graph,
            lambda1,
            per_var,
            weights,
            batch: Vec::new(),
            eps: vec![0.0; graph.domain_size() as usize],
            estimator: PoissonEnergyEstimator::new(graph, lambda2),
            cached_xi: None,
            accepted: 0,
            proposed: 0,
            metrics: None,
        }
    }

    /// First-minibatch expected size λ₁.
    pub fn lambda1(&self) -> f64 {
        self.lambda1
    }

    /// Second-minibatch expected size λ₂.
    pub fn lambda2(&self) -> f64 {
        self.estimator.lambda()
    }

    /// Retune λ₁: rebuilds the per-variable Poisson proposal tables.
    pub fn set_lambda1(&mut self, lambda1: f64) {
        let (per_var, weights) = local_proposal_tables(self.graph, lambda1);
        self.per_var = per_var;
        self.weights = weights;
        self.lambda1 = lambda1;
    }

    /// Retune λ₂: rebuilds the global estimator and drops the cached ξ
    /// (it was drawn under the old estimator).
    pub fn set_lambda2(&mut self, lambda2: f64) {
        self.estimator = PoissonEnergyEstimator::new(self.graph, lambda2);
        self.cached_xi = None;
    }

    /// Empirical acceptance rate so far.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

impl Sampler for DoubleMinGibbsSampler<'_> {
    // NOT site-local: the cached ξ is global augmented-space state, same
    // as MIN-Gibbs's ε.
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let g = self.graph;
        let d = g.domain_size() as usize;
        let cur = state[i] as usize;
        let factors = g.factors_of(i);
        let mut evals = 0u64;

        // Initialize the cached global estimate ξ_x lazily.
        let xi_x = match self.cached_xi {
            Some(x) => x,
            None => {
                let (x, ev) = self.estimator.estimate(g, state, rng);
                evals += ev;
                if let Some(m) = &self.metrics {
                    m.minibatch_global.record(ev);
                }
                x
            }
        };

        // First minibatch: sparse Poisson draw over A[i], O(λ₁).
        let batch = &mut self.batch;
        batch.clear();
        let wts = &self.weights[i];
        self.per_var[i].sample_into(rng, |pos, s| {
            batch.push((factors[pos], s as f64 * wts[pos]));
        });

        // Proposal energies ε_u: O(D·|S|).
        let saved = state[i];
        for u in 0..d {
            state[i] = u as u16;
            let mut sum = 0.0;
            for &(fid, w) in batch.iter() {
                sum += w * g.value(fid as usize, state);
            }
            self.eps[u] = sum;
        }
        state[i] = saved;
        let batch_size = batch.len() as u64;
        evals += d as u64 * batch_size;

        let v = sample_categorical_from_energies(rng, &self.eps);
        self.proposed += 1;

        // Second minibatch: fresh global estimate at the candidate y.
        state[i] = v as u16;
        let (xi_y, ev) = self.estimator.estimate(g, state, rng);
        evals += ev;
        state[i] = cur as u16;

        // a = exp(ξ_y − ξ_x + ε_{x(i)} − ε_{y(i)})
        let log_a = (xi_y - xi_x) + (self.eps[cur] - self.eps[v]);
        let accept = log_a >= 0.0 || rng.f64() < log_a.exp();
        if accept {
            state[i] = v as u16;
            self.cached_xi = Some(xi_y);
            self.accepted += 1;
        } else {
            self.cached_xi = Some(xi_x);
        }
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add(evals);
            m.minibatch_local.record(batch_size);
            m.minibatch_global.record(ev);
            m.proposals.add(1);
            m.accepts.add(accept as u64);
            m.estimator_energy.set(self.cached_xi.unwrap_or(0.0));
        }
        StepStats {
            variable: i,
            factor_evals: evals,
            accepted: accept,
        }
    }

    fn name(&self) -> &'static str {
        "doublemin-gibbs"
    }

    fn reset(&mut self, _state: &[u16], _rng: &mut dyn Rng) {
        self.cached_xi = None;
    }

    fn hyperparams(&self) -> Hyperparams {
        Hyperparams {
            lambda: Some(self.lambda1),
            lambda2: Some(self.estimator.lambda()),
            batch: None,
        }
    }

    fn set_hyperparams(&mut self, hp: &Hyperparams) -> bool {
        let mut changed = false;
        if let Some(l1) = hp.lambda {
            if l1 > 0.0 && l1 != self.lambda1 {
                self.set_lambda1(l1);
                changed = true;
            }
        }
        if let Some(l2) = hp.lambda2 {
            if l2 > 0.0 && l2 != self.estimator.lambda() {
                self.set_lambda2(l2);
                changed = true;
            }
        }
        changed
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }

    fn aux_energy(&self) -> Option<f64> {
        self.cached_xi
    }

    fn restore_aux_energy(&mut self, e: f64) {
        self.cached_xi = Some(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;
    use crate::samplers::test_support::{empirical_marginals, marginal_error_vs_exact};

    /// Theorem 5: the x-marginal of the stationary distribution is π.
    #[test]
    fn stationary_is_pi() {
        let g = models::tiny_random(3, 3, 0.5, 71);
        let s = g.stats().clone();
        let mut smp =
            DoubleMinGibbsSampler::new(&g, (s.l * s.l).max(2.0), (s.psi * s.psi).max(8.0));
        let m = empirical_marginals(&g, &mut smp, 500_000, 50_000, 72);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.02, "err = {err}");
    }

    /// Unbiased even when both batches are small (slow but correct).
    #[test]
    fn unbiased_with_small_batches() {
        let g = models::tiny_random(3, 2, 0.4, 73);
        let mut smp = DoubleMinGibbsSampler::new(&g, 1.0, 4.0);
        let m = empirical_marginals(&g, &mut smp, 800_000, 80_000, 74);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.03, "err = {err}");
    }

    /// The ξ cache must persist across rejections and refresh on accepts.
    #[test]
    fn xi_cache_lifecycle() {
        let g = models::tiny_random(4, 2, 0.5, 75);
        let mut smp = DoubleMinGibbsSampler::new(&g, 2.0, 10.0);
        let mut rng = Pcg64::seeded(76);
        let mut state = vec![0u16; 4];
        assert!(smp.cached_xi.is_none());
        smp.step(&mut state, &mut rng);
        assert!(smp.cached_xi.is_some());
        smp.reset(&state, &mut rng);
        assert!(smp.cached_xi.is_none());
    }

    /// With both λs large, DoubleMIN behaves like MGPMH with high
    /// acceptance.
    #[test]
    fn high_acceptance_with_large_batches() {
        let g = models::tiny_random(4, 3, 0.4, 77);
        let mut smp = DoubleMinGibbsSampler::new(&g, 300.0, 2000.0);
        let mut rng = Pcg64::seeded(78);
        let mut state = vec![0u16; 4];
        for _ in 0..10_000 {
            smp.step(&mut state, &mut rng);
        }
        assert!(
            smp.acceptance_rate() > 0.9,
            "acceptance = {}",
            smp.acceptance_rate()
        );
    }

    /// Per-step cost is O(Dλ₁ + λ₂), independent of Δ: check the count on
    /// a wide graph.
    #[test]
    fn cost_independent_of_delta() {
        let d = 4usize;
        let (l1, l2) = (3.0f64, 10.0f64);
        let mut means = Vec::new();
        for &n in &[20usize, 80] {
            let g = models::table1_workload(n, d as u16, 2.0);
            let mut smp = DoubleMinGibbsSampler::new(&g, l1, l2);
            let mut rng = Pcg64::seeded(79);
            let mut state = vec![0u16; n];
            smp.step(&mut state, &mut rng);
            let trials = 20_000;
            let total: u64 = (0..trials)
                .map(|_| smp.step(&mut state, &mut rng).factor_evals)
                .sum();
            means.push(total as f64 / trials as f64);
        }
        // Δ quadruples; the cost must stay within noise (< 15% change).
        let ratio = means[1] / means[0];
        assert!(
            (ratio - 1.0).abs() < 0.15,
            "cost grew with Δ: {means:?} ratio {ratio}"
        );
    }
}
