//! The paper's five samplers.
//!
//! | Algorithm | Type | Per-iteration cost (paper Table 1) |
//! |-----------|------|------------------------------------|
//! | [`GibbsSampler`] (Alg. 1) | exact | O(DΔ) |
//! | [`MinGibbsSampler`] (Alg. 2) | unbiased w/ Eq. (2) | O(DΨ²) |
//! | [`LocalMinibatchSampler`] (Alg. 3) | biased, no guarantee | O(BD) |
//! | [`MgpmhSampler`] (Alg. 4) | exact | O(DL² + Δ) |
//! | [`DoubleMinGibbsSampler`] (Alg. 5) | unbiased w/ Eq. (2) | O(DL² + Ψ²) |
//!
//! All samplers implement [`Sampler`] and are deterministic given the RNG
//! stream, so chains are replayable. Work is reported per step via
//! [`StepStats::factor_evals`] — the paper's cost unit (number of factor
//! evaluations) — which the Table-1 bench records alongside wall-clock.

pub mod dense;
pub mod doublemin;
pub mod estimator;
pub mod gibbs;
pub mod local;
pub mod mgpmh;
pub mod mingibbs;

pub use dense::DenseGibbsSampler;
pub use doublemin::DoubleMinGibbsSampler;
pub use estimator::{FixedBatchEstimator, PoissonEnergyEstimator};
pub use gibbs::{GibbsSampler, ScanOrder};
pub use local::LocalMinibatchSampler;
pub use mgpmh::MgpmhSampler;
pub use mingibbs::{MinGibbsSampler, NaiveMinGibbsSampler};

use std::sync::Arc;

use crate::graph::FactorGraph;
use crate::metrics::SamplerMetrics;
use crate::rng::{Rng, SparsePoissonSampler};

/// Per-step accounting: what happened and what it cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// The variable index that was (re)sampled.
    pub variable: usize,
    /// Number of factor evaluations performed — the paper's cost metric.
    pub factor_evals: u64,
    /// For MH-type samplers: whether the proposal was accepted.
    /// Always `true` for Gibbs-type samplers.
    pub accepted: bool,
}

/// The typed control surface over a sampler's tunable hyperparameters.
///
/// Each field is `Some` only where the sampler has that knob: λ for the
/// MGPMH / MIN-Gibbs family, λ₂ for DoubleMIN's second (global)
/// minibatch, B for Local Minibatch Gibbs. The adaptive controller
/// ([`crate::control`]) reads and writes these through
/// [`Sampler::hyperparams`] / [`Sampler::set_hyperparams`], and
/// checkpoints persist them so `--resume` continues with tuned values.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Hyperparams {
    /// Poisson minibatch rate λ (MGPMH, MIN-Gibbs, DoubleMIN's λ₁).
    pub lambda: Option<f64>,
    /// Second-minibatch rate λ₂ (DoubleMIN only).
    pub lambda2: Option<f64>,
    /// Fixed minibatch size B (Local Minibatch Gibbs).
    pub batch: Option<usize>,
}

impl Hyperparams {
    /// Just a λ.
    pub fn with_lambda(lambda: f64) -> Self {
        Self {
            lambda: Some(lambda),
            ..Self::default()
        }
    }

    /// Just a batch size B.
    pub fn with_batch(batch: usize) -> Self {
        Self {
            batch: Some(batch),
            ..Self::default()
        }
    }

    /// No knobs at all (e.g. exact Gibbs).
    pub fn is_empty(&self) -> bool {
        self.lambda.is_none() && self.lambda2.is_none() && self.batch.is_none()
    }
}

/// A single-site MCMC sampler over a factor graph.
///
/// The update surface is **site-addressable**: [`Sampler::select_site`]
/// is the scan policy (which variable to touch next) and
/// [`Sampler::update_site`] resamples exactly that variable. The
/// classic [`Sampler::step`] is a default method composing the two, so
/// serial callers are unchanged while schedulers — in particular the
/// chromatic parallel executor in [`crate::runtime::parallel`] — can
/// drive sites directly.
pub trait Sampler {
    /// Resample variable `site` in place, touching only that variable's
    /// neighborhood (plus any sampler-internal caches). Returns the
    /// per-step accounting with `variable == site`.
    fn update_site(&mut self, site: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats;

    /// The scan policy: pick the next site to update. The default is the
    /// random scan every sampler in the paper uses — one uniform draw
    /// from the RNG stream, exactly the draw the pre-split `step` made
    /// first, so chains replay bit-identically across the API change.
    fn select_site(&mut self, state: &[u16], rng: &mut dyn Rng) -> usize {
        rng.index(state.len())
    }

    /// Advance the chain by one update; `state` is mutated in place.
    /// Default: `select_site` then `update_site`.
    fn step(&mut self, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let site = self.select_site(state, rng);
        self.update_site(site, state, rng)
    }

    /// Whether `update_site` touches only the site's neighborhood, with
    /// no sampler-global mutable caches. Only site-local samplers are
    /// safe under the chromatic parallel executor, which updates many
    /// conditionally independent sites concurrently. `false` for the
    /// MIN-Gibbs family: their cached augmented-space energy (ε / ξ) is
    /// global state serializing every update.
    fn is_site_local(&self) -> bool {
        false
    }

    /// Human-readable name, used in reports and CSV output.
    fn name(&self) -> &'static str;

    /// Reset sampler-internal caches (e.g. MIN-Gibbs's cached energy)
    /// after an external change to the state. Default: no caches.
    fn reset(&mut self, _state: &[u16], _rng: &mut dyn Rng) {}

    /// Current tunable hyperparameters. Samplers with nothing to tune
    /// (exact Gibbs) return the empty default.
    fn hyperparams(&self) -> Hyperparams {
        Hyperparams::default()
    }

    /// Apply new hyperparameters mid-run. Fields that are `None` — or
    /// that the sampler does not have — are left unchanged; non-positive
    /// or identical values are ignored. Returns `true` iff anything
    /// actually changed (the controller counts these as adjustments).
    fn set_hyperparams(&mut self, _hp: &Hyperparams) -> bool {
        false
    }

    /// Where an instrumented sampler stores its metrics handle. The
    /// default (`None`) drops the attachment; instrumented samplers
    /// return their `Option<Arc<SamplerMetrics>>` field and inherit the
    /// full [`Sampler::attach_metrics`] wiring from this one line.
    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        None
    }

    /// Publish the configured hyperparameters to the shared gauges. The
    /// default derives everything from [`Sampler::hyperparams`]: λ → the
    /// `sampler_lambda` gauge (B reuses it, as before this API), λ₂ →
    /// `sampler_lambda2`. Called on attach and re-called by the
    /// controller after every adjustment.
    fn publish_hyperparams(&self, m: &SamplerMetrics) {
        let hp = self.hyperparams();
        if let Some(l) = hp.lambda {
            m.lambda.set(l);
        }
        if let Some(b) = hp.batch {
            m.lambda.set(b as f64);
        }
        if let Some(l2) = hp.lambda2 {
            m.lambda2.set(l2);
        }
    }

    /// Attach shared instrumentation. The default publishes the gauges
    /// and stores the handle in [`Sampler::metrics_slot`]; samplers
    /// without a slot ignore the attachment. An unattached sampler pays
    /// only an `Option` branch per step.
    fn attach_metrics(&mut self, m: Arc<SamplerMetrics>) {
        self.publish_hyperparams(&m);
        if let Some(slot) = self.metrics_slot() {
            *slot = Some(m);
        }
    }

    /// The augmented-space energy cache (MIN-Gibbs's ε, DoubleMIN's ξ),
    /// if the sampler carries one and it is initialized. Checkpointed so
    /// `--resume` replays the uninterrupted run bit-exactly.
    fn aux_energy(&self) -> Option<f64> {
        None
    }

    /// Restore a checkpointed [`Sampler::aux_energy`]. Call after
    /// [`Sampler::reset`] (which clears the cache).
    fn restore_aux_energy(&mut self, _e: f64) {}
}

/// Per-variable sparse Poisson proposal tables shared by MGPMH and
/// DoubleMIN-Gibbs: over each A\[i\], rates λ·M_φ/L and the matching
/// importance weights L/(λ·M_φ). Rebuilt whenever the controller retunes
/// λ.
pub(crate) fn local_proposal_tables(
    graph: &FactorGraph,
    lambda: f64,
) -> (Vec<SparsePoissonSampler>, Vec<Vec<f64>>) {
    assert!(lambda > 0.0, "λ must be positive");
    let l = graph.stats().l;
    assert!(l > 0.0, "graph has zero local energy");
    let n = graph.n();
    let mut per_var = Vec::with_capacity(n);
    let mut weights = Vec::with_capacity(n);
    for i in 0..n {
        let rates: Vec<f64> = graph
            .factors_of(i)
            .iter()
            .map(|&fid| lambda * graph.max_energy(fid as usize) / l)
            .collect();
        let w: Vec<f64> = graph
            .factors_of(i)
            .iter()
            .map(|&fid| {
                let m = graph.max_energy(fid as usize);
                if m > 0.0 {
                    l / (lambda * m)
                } else {
                    0.0
                }
            })
            .collect();
        per_var.push(SparsePoissonSampler::new(&rates));
        weights.push(w);
    }
    (per_var, weights)
}

/// Which conditional-energy evaluation path Gibbs-type samplers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnergyPath {
    /// Per-factor evaluation loop: O(DΔ) — the paper's Gibbs cost model,
    /// and the honest baseline for the Table-1 reproduction.
    Generic,
    /// Structure-aware accumulation: O(Δ + D) for pairwise factors.
    Specialized,
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::analysis;
    use crate::graph::FactorGraph;
    use crate::rng::Pcg64;

    use super::Sampler;

    /// Run `iters` steps and return empirical marginals from the samples.
    pub fn empirical_marginals(
        g: &FactorGraph,
        sampler: &mut dyn Sampler,
        iters: usize,
        burnin: usize,
        seed: u64,
    ) -> Vec<Vec<f64>> {
        let mut rng = Pcg64::seeded(seed);
        let n = g.n();
        let d = g.domain_size() as usize;
        let mut state = vec![0u16; n];
        sampler.reset(&state, &mut rng);
        let mut counts = vec![vec![0u64; d]; n];
        for it in 0..iters {
            sampler.step(&mut state, &mut rng);
            if it >= burnin {
                for (i, &v) in state.iter().enumerate() {
                    counts[i][v as usize] += 1;
                }
            }
        }
        let total = (iters - burnin) as f64;
        counts
            .into_iter()
            .map(|row| row.into_iter().map(|c| c as f64 / total).collect())
            .collect()
    }

    /// Max absolute deviation between empirical and exact marginals.
    pub fn marginal_error_vs_exact(g: &FactorGraph, marginals: &[Vec<f64>]) -> f64 {
        let exact = analysis::exact_marginals(g);
        let mut worst = 0.0f64;
        for (emp, ex) in marginals.iter().zip(exact.iter()) {
            for (a, b) in emp.iter().zip(ex.iter()) {
                worst = worst.max((a - b).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;

    /// All five samplers must converge to the same stationary marginals on
    /// a tiny enumerable model — the cross-sampler agreement test.
    #[test]
    fn all_samplers_agree_on_tiny_model() {
        let g = models::tiny_random(3, 3, 0.8, 42);
        let stats = g.stats().clone();
        let lambda1 = (stats.l * stats.l).max(2.0);
        let lambda2 = (stats.psi * stats.psi).max(4.0);

        let mut samplers: Vec<Box<dyn Sampler>> = vec![
            Box::new(GibbsSampler::new(&g, EnergyPath::Specialized)),
            Box::new(MinGibbsSampler::new(&g, lambda2)),
            Box::new(LocalMinibatchSampler::new(&g, 2)),
            Box::new(MgpmhSampler::new(&g, lambda1)),
            Box::new(DoubleMinGibbsSampler::new(&g, lambda1, lambda2)),
        ];
        let iters = 400_000;
        for s in samplers.iter_mut() {
            let m = test_support::empirical_marginals(&g, s.as_mut(), iters, iters / 10, 7);
            let err = test_support::marginal_error_vs_exact(&g, &m);
            // Local minibatch is biased; everything else is exact/unbiased.
            let tol = if s.name() == "local-minibatch" { 0.08 } else { 0.02 };
            assert!(err < tol, "{}: marginal error {err}", s.name());
        }
    }

    /// The typed control surface: every tunable sampler round-trips its
    /// hyperparameters through `hyperparams` / `set_hyperparams`, ignores
    /// knobs it does not have, and reports no-op updates as `false`.
    #[test]
    fn hyperparam_surface_roundtrips() {
        let g = models::tiny_random(3, 3, 0.8, 42);

        let mut gibbs = GibbsSampler::new(&g, EnergyPath::Specialized);
        assert!(gibbs.hyperparams().is_empty());
        assert!(!gibbs.set_hyperparams(&Hyperparams::with_lambda(9.0)));

        let mut mgpmh = MgpmhSampler::new(&g, 4.0);
        assert_eq!(mgpmh.hyperparams().lambda, Some(4.0));
        assert!(mgpmh.set_hyperparams(&Hyperparams::with_lambda(2.0)));
        assert_eq!(mgpmh.lambda(), 2.0);
        assert!(!mgpmh.set_hyperparams(&Hyperparams::with_lambda(2.0)));
        assert!(!mgpmh.set_hyperparams(&Hyperparams::with_batch(7)));

        let mut local = LocalMinibatchSampler::new(&g, 2);
        assert_eq!(local.hyperparams().batch, Some(2));
        assert!(local.set_hyperparams(&Hyperparams::with_batch(3)));
        assert_eq!(local.batch(), 3);
        assert!(!local.set_hyperparams(&Hyperparams::with_batch(0)));

        let mut mg = MinGibbsSampler::new(&g, 16.0);
        assert_eq!(mg.hyperparams().lambda, Some(16.0));
        assert!(mg.set_hyperparams(&Hyperparams::with_lambda(8.0)));
        assert_eq!(mg.lambda(), 8.0);

        let mut dm = DoubleMinGibbsSampler::new(&g, 4.0, 32.0);
        let hp = dm.hyperparams();
        assert_eq!((hp.lambda, hp.lambda2), (Some(4.0), Some(32.0)));
        let update = Hyperparams {
            lambda: Some(3.0),
            lambda2: Some(24.0),
            batch: None,
        };
        assert!(dm.set_hyperparams(&update));
        assert_eq!((dm.lambda1(), dm.lambda2()), (3.0, 24.0));
    }

    /// Retuning λ mid-chain must not bias the stationary distribution:
    /// MGPMH keeps exactly π because each step is a valid MH kernel for
    /// π regardless of the proposal's λ.
    #[test]
    fn mgpmh_stays_unbiased_across_retuning() {
        let g = models::tiny_random(3, 3, 0.8, 44);
        let mut s = MgpmhSampler::new(&g, 1.0);
        let mut rng = Pcg64::seeded(45);
        let n = g.n();
        let d = g.domain_size() as usize;
        let mut state = vec![0u16; n];
        let (iters, burnin) = (400_000usize, 40_000usize);
        let mut counts = vec![vec![0u64; d]; n];
        for it in 0..iters {
            // Sweep λ across a ×16 range every quarter of the run.
            if it % (iters / 4) == 0 && it > 0 {
                let cur = s.lambda();
                s.set_hyperparams(&Hyperparams::with_lambda(cur * 2.5));
            }
            s.step(&mut state, &mut rng);
            if it >= burnin {
                for (i, &v) in state.iter().enumerate() {
                    counts[i][v as usize] += 1;
                }
            }
        }
        let total = (iters - burnin) as f64;
        let marginals: Vec<Vec<f64>> = counts
            .into_iter()
            .map(|row| row.into_iter().map(|c| c as f64 / total).collect())
            .collect();
        let err = test_support::marginal_error_vs_exact(&g, &marginals);
        assert!(err < 0.02, "retuned chain biased: err = {err}");
    }

    /// Chains must be exactly reproducible for a fixed seed.
    #[test]
    fn chains_are_deterministic() {
        let g = models::tiny_random(4, 3, 1.0, 1);
        for mk in 0..2 {
            let run = |seed: u64| {
                let mut s: Box<dyn Sampler> = if mk == 0 {
                    Box::new(GibbsSampler::new(&g, EnergyPath::Generic))
                } else {
                    Box::new(MgpmhSampler::new(&g, 4.0))
                };
                let mut rng = Pcg64::seeded(seed);
                let mut state = vec![0u16; g.n()];
                s.reset(&state, &mut rng);
                for _ in 0..5000 {
                    s.step(&mut state, &mut rng);
                }
                state
            };
            assert_eq!(run(3), run(3));
        }
    }
}
