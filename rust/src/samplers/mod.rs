//! The paper's five samplers.
//!
//! | Algorithm | Type | Per-iteration cost (paper Table 1) |
//! |-----------|------|------------------------------------|
//! | [`GibbsSampler`] (Alg. 1) | exact | O(DΔ) |
//! | [`MinGibbsSampler`] (Alg. 2) | unbiased w/ Eq. (2) | O(DΨ²) |
//! | [`LocalMinibatchSampler`] (Alg. 3) | biased, no guarantee | O(BD) |
//! | [`MgpmhSampler`] (Alg. 4) | exact | O(DL² + Δ) |
//! | [`DoubleMinGibbsSampler`] (Alg. 5) | unbiased w/ Eq. (2) | O(DL² + Ψ²) |
//!
//! All samplers implement [`Sampler`] and are deterministic given the RNG
//! stream, so chains are replayable. Work is reported per step via
//! [`StepStats::factor_evals`] — the paper's cost unit (number of factor
//! evaluations) — which the Table-1 bench records alongside wall-clock.

pub mod dense;
pub mod doublemin;
pub mod estimator;
pub mod gibbs;
pub mod local;
pub mod mgpmh;
pub mod mingibbs;

pub use dense::DenseGibbsSampler;
pub use doublemin::DoubleMinGibbsSampler;
pub use estimator::{FixedBatchEstimator, PoissonEnergyEstimator};
pub use gibbs::{GibbsSampler, ScanOrder};
pub use local::LocalMinibatchSampler;
pub use mgpmh::MgpmhSampler;
pub use mingibbs::{MinGibbsSampler, NaiveMinGibbsSampler};

use std::sync::Arc;

use crate::metrics::SamplerMetrics;
use crate::rng::Rng;

/// Per-step accounting: what happened and what it cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// The variable index that was (re)sampled.
    pub variable: usize,
    /// Number of factor evaluations performed — the paper's cost metric.
    pub factor_evals: u64,
    /// For MH-type samplers: whether the proposal was accepted.
    /// Always `true` for Gibbs-type samplers.
    pub accepted: bool,
}

/// A single-site MCMC sampler over a factor graph.
pub trait Sampler {
    /// Advance the chain by one update; `state` is mutated in place.
    fn step(&mut self, state: &mut [u16], rng: &mut dyn Rng) -> StepStats;

    /// Human-readable name, used in reports and CSV output.
    fn name(&self) -> &'static str;

    /// Reset sampler-internal caches (e.g. MIN-Gibbs's cached energy)
    /// after an external change to the state. Default: no caches.
    fn reset(&mut self, _state: &[u16], _rng: &mut dyn Rng) {}

    /// Attach shared instrumentation. Samplers that support it report
    /// steps, factor evals, minibatch sizes, MH accept/propose counts,
    /// and estimator statistics through the handles; the default ignores
    /// the attachment. An unattached sampler pays only an `Option` branch
    /// per step.
    fn attach_metrics(&mut self, _m: Arc<SamplerMetrics>) {}
}

/// Which conditional-energy evaluation path Gibbs-type samplers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnergyPath {
    /// Per-factor evaluation loop: O(DΔ) — the paper's Gibbs cost model,
    /// and the honest baseline for the Table-1 reproduction.
    Generic,
    /// Structure-aware accumulation: O(Δ + D) for pairwise factors.
    Specialized,
}

#[cfg(test)]
pub(crate) mod test_support {
    use crate::analysis;
    use crate::graph::FactorGraph;
    use crate::rng::Pcg64;

    use super::Sampler;

    /// Run `iters` steps and return empirical marginals from the samples.
    pub fn empirical_marginals(
        g: &FactorGraph,
        sampler: &mut dyn Sampler,
        iters: usize,
        burnin: usize,
        seed: u64,
    ) -> Vec<Vec<f64>> {
        let mut rng = Pcg64::seeded(seed);
        let n = g.n();
        let d = g.domain_size() as usize;
        let mut state = vec![0u16; n];
        sampler.reset(&state, &mut rng);
        let mut counts = vec![vec![0u64; d]; n];
        for it in 0..iters {
            sampler.step(&mut state, &mut rng);
            if it >= burnin {
                for (i, &v) in state.iter().enumerate() {
                    counts[i][v as usize] += 1;
                }
            }
        }
        let total = (iters - burnin) as f64;
        counts
            .into_iter()
            .map(|row| row.into_iter().map(|c| c as f64 / total).collect())
            .collect()
    }

    /// Max absolute deviation between empirical and exact marginals.
    pub fn marginal_error_vs_exact(g: &FactorGraph, marginals: &[Vec<f64>]) -> f64 {
        let exact = analysis::exact_marginals(g);
        let mut worst = 0.0f64;
        for (emp, ex) in marginals.iter().zip(exact.iter()) {
            for (a, b) in emp.iter().zip(ex.iter()) {
                worst = worst.max((a - b).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;

    /// All five samplers must converge to the same stationary marginals on
    /// a tiny enumerable model — the cross-sampler agreement test.
    #[test]
    fn all_samplers_agree_on_tiny_model() {
        let g = models::tiny_random(3, 3, 0.8, 42);
        let stats = g.stats().clone();
        let lambda1 = (stats.l * stats.l).max(2.0);
        let lambda2 = (stats.psi * stats.psi).max(4.0);

        let mut samplers: Vec<Box<dyn Sampler>> = vec![
            Box::new(GibbsSampler::new(&g, EnergyPath::Specialized)),
            Box::new(MinGibbsSampler::new(&g, lambda2)),
            Box::new(LocalMinibatchSampler::new(&g, 2)),
            Box::new(MgpmhSampler::new(&g, lambda1)),
            Box::new(DoubleMinGibbsSampler::new(&g, lambda1, lambda2)),
        ];
        let iters = 400_000;
        for s in samplers.iter_mut() {
            let m = test_support::empirical_marginals(&g, s.as_mut(), iters, iters / 10, 7);
            let err = test_support::marginal_error_vs_exact(&g, &m);
            // Local minibatch is biased; everything else is exact/unbiased.
            let tol = if s.name() == "local-minibatch" { 0.08 } else { 0.02 };
            assert!(err < tol, "{}: marginal error {err}", s.name());
        }
    }

    /// Chains must be exactly reproducible for a fixed seed.
    #[test]
    fn chains_are_deterministic() {
        let g = models::tiny_random(4, 3, 1.0, 1);
        for mk in 0..2 {
            let run = |seed: u64| {
                let mut s: Box<dyn Sampler> = if mk == 0 {
                    Box::new(GibbsSampler::new(&g, EnergyPath::Generic))
                } else {
                    Box::new(MgpmhSampler::new(&g, 4.0))
                };
                let mut rng = Pcg64::seeded(seed);
                let mut state = vec![0u16; g.n()];
                s.reset(&state, &mut rng);
                for _ in 0..5000 {
                    s.step(&mut state, &mut rng);
                }
                state
            };
            assert_eq!(run(3), run(3));
        }
    }
}
