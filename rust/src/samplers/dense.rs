//! Dense-model Gibbs sampler: the production fast path for fully
//! connected pairwise models (the paper's §B workloads).
//!
//! Statistically identical to [`super::GibbsSampler`]; the only change is
//! where the conditional energies come from — one contiguous row of the
//! dense weight matrix ([`DenseModel::cond_energies_row`]) instead of a
//! walk over Δ factor objects. See EXPERIMENTS.md §Perf for the measured
//! speedup.

use std::sync::Arc;

use crate::graph::models::DenseModel;
use crate::metrics::SamplerMetrics;
use crate::rng::{sample_categorical_from_energies, Rng};

use super::{Sampler, StepStats};

/// Gibbs sampling specialized to a [`DenseModel`].
pub struct DenseGibbsSampler<'m> {
    model: &'m DenseModel,
    eps: Vec<f64>,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'m> DenseGibbsSampler<'m> {
    /// Create for a dense model.
    pub fn new(model: &'m DenseModel) -> Self {
        Self {
            model,
            eps: vec![0.0; model.graph.domain_size() as usize],
            metrics: None,
        }
    }
}

impl Sampler for DenseGibbsSampler<'_> {
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let n = self.model.graph.n();
        self.model.cond_energies_row(state, i, &mut self.eps);
        let v = sample_categorical_from_energies(rng, &self.eps);
        state[i] = v as u16;
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add((n - 1) as u64);
        }
        StepStats {
            variable: i,
            factor_evals: (n - 1) as u64,
            accepted: true,
        }
    }

    fn is_site_local(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "dense-gibbs"
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;
    use crate::samplers::{EnergyPath, GibbsSampler};

    /// Row-based and factor-based conditional energies must agree exactly
    /// enough that same-seed chains follow identical trajectories.
    #[test]
    fn identical_trajectory_to_factor_gibbs() {
        let m = models::potts_rbf(4, 6, 2.2, 1.5);
        let run = |dense: bool| -> Vec<u16> {
            let mut rng = Pcg64::seeded(77);
            let mut state = vec![0u16; m.graph.n()];
            if dense {
                let mut s = DenseGibbsSampler::new(&m);
                for _ in 0..30_000 {
                    s.step(&mut state, &mut rng);
                }
            } else {
                let mut s = GibbsSampler::new(&m.graph, EnergyPath::Specialized);
                for _ in 0..30_000 {
                    s.step(&mut state, &mut rng);
                }
            }
            state
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn cond_row_matches_graph() {
        let m = models::paper_potts();
        let mut rng = Pcg64::seeded(3);
        let d = 10usize;
        let mut state: Vec<u16> = (0..m.graph.n()).map(|_| rng.index(d) as u16).collect();
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        for &i in &[0usize, 123, 399] {
            m.cond_energies_row(&state, i, &mut a);
            m.graph.cond_energies_fast(&mut state, i, &mut b);
            for u in 0..d {
                assert!((a[u] - b[u]).abs() < 1e-9, "i={i} u={u}: {a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn works_on_ising_weights() {
        let m = models::ising_rbf(5, 1.3, 1.5);
        let mut rng = Pcg64::seeded(9);
        let mut state = vec![0u16; 25];
        let mut s = DenseGibbsSampler::new(&m);
        for _ in 0..5_000 {
            let st = s.step(&mut state, &mut rng);
            assert_eq!(st.factor_evals, 24);
        }
        assert!(state.iter().all(|&v| v < 2));
    }
}
