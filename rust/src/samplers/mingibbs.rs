//! Algorithm 2: MIN-Gibbs — minibatch Gibbs on the augmented space Ω × ℝ.
//!
//! Replaces the exact conditional energies with draws from the Eq. (2)
//! estimator and *caches* the current state's energy estimate (the ε
//! component of the augmented state), re-estimating only the D−1
//! alternative values each step. With the bias-adjusted estimator the
//! marginal stationary distribution in x is exactly π (Theorem 1 +
//! Lemma 1); with λ = Θ(Ψ²) the spectral gap is within an O(1) factor of
//! vanilla Gibbs (Theorem 2 + Lemma 2).

use std::sync::Arc;

use crate::graph::FactorGraph;
use crate::metrics::SamplerMetrics;
use crate::rng::{sample_categorical_from_energies, Rng};

use super::{
    estimator::{FixedBatchEstimator, PoissonEnergyEstimator},
    Hyperparams, Sampler, StepStats,
};

/// MIN-Gibbs sampler (paper Algorithm 2) with the Eq. (2) estimator.
pub struct MinGibbsSampler<'g> {
    graph: &'g FactorGraph,
    estimator: PoissonEnergyEstimator,
    /// Cached ε component of the augmented state (x, ε).
    cached_energy: Option<f64>,
    eps: Vec<f64>,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'g> MinGibbsSampler<'g> {
    /// Create with expected (global) minibatch size λ. The paper's recipe
    /// for an O(1) convergence penalty is λ = Θ(Ψ²) (Lemma 2).
    pub fn new(graph: &'g FactorGraph, lambda: f64) -> Self {
        Self {
            graph,
            estimator: PoissonEnergyEstimator::new(graph, lambda),
            cached_energy: None,
            eps: vec![0.0; graph.domain_size() as usize],
            metrics: None,
        }
    }

    /// Expected minibatch size λ.
    pub fn lambda(&self) -> f64 {
        self.estimator.lambda()
    }

    /// The cached energy estimate ε for the current state, if initialized.
    pub fn cached_energy(&self) -> Option<f64> {
        self.cached_energy
    }
}

impl Sampler for MinGibbsSampler<'_> {
    // NOT site-local (`is_site_local` stays false): the cached ε is
    // global augmented-space state — every update rewrites it, so
    // concurrent site updates would race on it semantically.
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let g = self.graph;
        let d = g.domain_size() as usize;
        let cur = state[i] as usize;
        let mut evals = 0u64;

        // ε_{x(i)} ← cached ε (initialize lazily on first step).
        let cached = match self.cached_energy {
            Some(e) => e,
            None => {
                let (e, ev) = self.estimator.estimate(g, state, rng);
                evals += ev;
                if let Some(m) = &self.metrics {
                    m.minibatch_global.record(ev);
                }
                e
            }
        };
        self.eps[cur] = cached;

        // Fresh estimate ε_u ~ μ_{x_{i→u}} for every other value.
        for u in 0..d {
            if u == cur {
                continue;
            }
            state[i] = u as u16;
            let (e, ev) = self.estimator.estimate(g, state, rng);
            evals += ev;
            if let Some(m) = &self.metrics {
                m.minibatch_global.record(ev);
            }
            self.eps[u] = e;
        }
        state[i] = cur as u16;

        let v = sample_categorical_from_energies(rng, &self.eps);
        state[i] = v as u16;
        self.cached_energy = Some(self.eps[v]);
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add(evals);
            m.estimator_energy.set(self.eps[v]);
        }
        StepStats {
            variable: i,
            factor_evals: evals,
            accepted: true,
        }
    }

    fn name(&self) -> &'static str {
        "min-gibbs"
    }

    fn reset(&mut self, _state: &[u16], _rng: &mut dyn Rng) {
        self.cached_energy = None;
    }

    fn hyperparams(&self) -> Hyperparams {
        Hyperparams::with_lambda(self.estimator.lambda())
    }

    fn set_hyperparams(&mut self, hp: &Hyperparams) -> bool {
        match hp.lambda {
            Some(l) if l > 0.0 && l != self.estimator.lambda() => {
                self.estimator = PoissonEnergyEstimator::new(self.graph, l);
                // The cached ε was drawn under the old estimator; drop it
                // so the next step re-estimates on the new distribution.
                self.cached_energy = None;
                true
            }
            _ => false,
        }
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }

    fn aux_energy(&self) -> Option<f64> {
        self.cached_energy
    }

    fn restore_aux_energy(&mut self, e: f64) {
        self.cached_energy = Some(e);
    }
}

/// MIN-Gibbs with the *naive* fixed-batch estimator — the ablation
/// baseline the paper's §2 contrasts against. The estimator is unbiased
/// in ε but NOT in exp(ε), so this chain converges to a *tempered* (wrong)
/// distribution; it exists to demonstrate, in tests and the ablation
/// bench, exactly the bias that the Eq. (2) adjustment removes.
pub struct NaiveMinGibbsSampler<'g> {
    graph: &'g FactorGraph,
    estimator: FixedBatchEstimator,
    cached_energy: Option<f64>,
    eps: Vec<f64>,
    metrics: Option<Arc<SamplerMetrics>>,
}

impl<'g> NaiveMinGibbsSampler<'g> {
    /// Create with fixed minibatch size `batch` (uniform, with
    /// replacement, Horvitz–Thompson scaled).
    pub fn new(graph: &'g FactorGraph, batch: usize) -> Self {
        Self {
            graph,
            estimator: FixedBatchEstimator::new(batch),
            cached_energy: None,
            eps: vec![0.0; graph.domain_size() as usize],
            metrics: None,
        }
    }
}

impl Sampler for NaiveMinGibbsSampler<'_> {
    fn update_site(&mut self, i: usize, state: &mut [u16], rng: &mut dyn Rng) -> StepStats {
        let g = self.graph;
        let d = g.domain_size() as usize;
        let cur = state[i] as usize;
        let mut evals = 0u64;
        let cached = match self.cached_energy {
            Some(e) => e,
            None => {
                let (e, ev) = self.estimator.estimate(g, state, rng);
                evals += ev;
                e
            }
        };
        self.eps[cur] = cached;
        for u in 0..d {
            if u == cur {
                continue;
            }
            state[i] = u as u16;
            let (e, ev) = self.estimator.estimate(g, state, rng);
            evals += ev;
            self.eps[u] = e;
        }
        state[i] = cur as u16;
        let v = sample_categorical_from_energies(rng, &self.eps);
        state[i] = v as u16;
        self.cached_energy = Some(self.eps[v]);
        if let Some(m) = &self.metrics {
            m.steps.add(1);
            m.factor_evals.add(evals);
            m.estimator_energy.set(self.eps[v]);
        }
        StepStats {
            variable: i,
            factor_evals: evals,
            accepted: true,
        }
    }

    fn name(&self) -> &'static str {
        "naive-min-gibbs"
    }

    fn reset(&mut self, _state: &[u16], _rng: &mut dyn Rng) {
        self.cached_energy = None;
    }

    fn metrics_slot(&mut self) -> Option<&mut Option<Arc<SamplerMetrics>>> {
        Some(&mut self.metrics)
    }

    fn aux_energy(&self) -> Option<f64> {
        self.cached_energy
    }

    fn restore_aux_energy(&mut self, e: f64) {
        self.cached_energy = Some(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::rng::Pcg64;
    use crate::samplers::test_support::{empirical_marginals, marginal_error_vs_exact};

    /// Theorem 1 + Lemma 1: with the Eq. (2) estimator the x-marginal of
    /// the stationary distribution is exactly π.
    #[test]
    fn unbiased_stationary_marginals() {
        let g = models::tiny_random(3, 2, 0.6, 21);
        let psi = g.stats().psi;
        let mut s = MinGibbsSampler::new(&g, (psi * psi).max(8.0));
        let m = empirical_marginals(&g, &mut s, 400_000, 40_000, 22);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.015, "err = {err}");
    }

    /// Small λ slows mixing but must NOT bias the chain (unlike naive
    /// minibatching): marginals still converge to π.
    #[test]
    fn unbiased_even_with_small_lambda() {
        let g = models::tiny_random(3, 2, 0.3, 23);
        let mut s = MinGibbsSampler::new(&g, 3.0);
        let m = empirical_marginals(&g, &mut s, 600_000, 60_000, 24);
        let err = marginal_error_vs_exact(&g, &m);
        assert!(err < 0.025, "err = {err}");
    }

    /// The energy cache must follow the chain: after a step the cached ε
    /// equals the ε_v selected for the new state.
    #[test]
    fn cache_follows_state() {
        let g = models::tiny_random(4, 3, 0.5, 25);
        let mut s = MinGibbsSampler::new(&g, 20.0);
        let mut rng = Pcg64::seeded(26);
        let mut state = vec![0u16; 4];
        assert!(s.cached_energy().is_none());
        s.step(&mut state, &mut rng);
        assert!(s.cached_energy().is_some());
        s.reset(&state, &mut rng);
        assert!(s.cached_energy().is_none());
    }

    /// The ablation claim (paper §2 contribution 2): with the naive
    /// fixed-batch estimator the chain is *biased* — its stationary
    /// marginals measurably deviate from π where the Eq. (2) chain's do
    /// not, on a model chosen to make the Jensen gap visible.
    #[test]
    fn naive_estimator_biases_the_chain() {
        // Strong asymmetric model: one dominant table factor makes the
        // exp-bias visible in the marginals.
        let mut b = crate::graph::FactorGraphBuilder::new(3, 2);
        b.add_potts_pair(0, 1, 1.6)
            .add_potts_pair(1, 2, 1.2)
            .add_table(vec![0], vec![0.0, 1.8]);
        let g = b.build();
        let iters = 600_000;

        let mut naive = NaiveMinGibbsSampler::new(&g, 1);
        let m = empirical_marginals(&g, &mut naive, iters, iters / 10, 91);
        let err_naive = marginal_error_vs_exact(&g, &m);

        let mut adjusted = MinGibbsSampler::new(&g, 3.0);
        let m = empirical_marginals(&g, &mut adjusted, iters, iters / 10, 91);
        let err_adjusted = marginal_error_vs_exact(&g, &m);

        assert!(
            err_naive > 0.03,
            "naive minibatching should be visibly biased (err {err_naive})"
        );
        assert!(
            err_adjusted < err_naive / 2.0,
            "Eq.(2) chain (err {err_adjusted}) should beat naive (err {err_naive})"
        );
    }

    /// Per-step cost concentrates near D·λ factor evaluations. (Needs a
    /// graph with ≫ λ factors so multinomial collisions — which merge
    /// into a single evaluation — are rare.)
    #[test]
    fn cost_scales_with_d_lambda() {
        let g = models::potts_random(60, 4, 12, 0.5, 27);
        let lambda = 12.0;
        let mut s = MinGibbsSampler::new(&g, lambda);
        let mut rng = Pcg64::seeded(28);
        let mut state = vec![0u16; 60];
        s.step(&mut state, &mut rng); // warm the cache
        let trials = 20_000;
        let total: u64 = (0..trials)
            .map(|_| s.step(&mut state, &mut rng).factor_evals)
            .sum();
        let mean = total as f64 / trials as f64;
        let want = 3.0 * lambda; // (D−1)=3 fresh estimates per step
        assert!(
            (mean - want).abs() / want < 0.25,
            "mean evals {mean}, want ≈ {want}"
        );
    }
}
