//! Workload construction for the paper's experiments.
//!
//! Each experiment in DESIGN.md's index maps to one function here; the
//! `cargo bench` targets and the CLI subcommands both call these so there
//! is a single source of truth for the parameters.

use crate::graph::models::{self, DenseModel};
use crate::graph::FactorGraph;
use crate::samplers::{
    DoubleMinGibbsSampler, EnergyPath, GibbsSampler, LocalMinibatchSampler, MgpmhSampler,
    MinGibbsSampler, Sampler,
};

/// Which sampler to construct, with its batch parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplerSpec {
    /// Vanilla Gibbs (Algorithm 1) with the given evaluation path.
    Gibbs(EnergyPath),
    /// MIN-Gibbs (Algorithm 2) with global expected batch λ.
    MinGibbs { lambda: f64 },
    /// Local Minibatch Gibbs (Algorithm 3) with fixed batch B.
    Local { batch: usize },
    /// MGPMH (Algorithm 4) with local expected batch λ.
    Mgpmh { lambda: f64 },
    /// DoubleMIN-Gibbs (Algorithm 5) with batch sizes (λ₁, λ₂).
    DoubleMin { lambda1: f64, lambda2: f64 },
}

impl SamplerSpec {
    /// Instantiate against a graph.
    pub fn build<'g>(&self, g: &'g FactorGraph) -> Box<dyn Sampler + 'g> {
        match *self {
            SamplerSpec::Gibbs(path) => Box::new(GibbsSampler::new(g, path)),
            SamplerSpec::MinGibbs { lambda } => Box::new(MinGibbsSampler::new(g, lambda)),
            SamplerSpec::Local { batch } => Box::new(LocalMinibatchSampler::new(g, batch)),
            SamplerSpec::Mgpmh { lambda } => Box::new(MgpmhSampler::new(g, lambda)),
            SamplerSpec::DoubleMin { lambda1, lambda2 } => {
                Box::new(DoubleMinGibbsSampler::new(g, lambda1, lambda2))
            }
        }
    }

    /// Whether the sampler's update touches only the chosen site's
    /// neighborhood — i.e. [`Sampler::is_site_local`] holds for the
    /// built sampler — which is what the chromatic parallel executor
    /// ([`crate::runtime::parallel`]) requires. MIN-Gibbs and DoubleMIN
    /// carry a *global* cached augmented-space energy, so concurrent
    /// site updates would corrupt it.
    pub fn supports_parallel(&self) -> bool {
        matches!(
            self,
            SamplerSpec::Gibbs(_) | SamplerSpec::Local { .. } | SamplerSpec::Mgpmh { .. }
        )
    }

    /// Label for reports ("gibbs", "min-gibbs λ=2Ψ²", ...).
    pub fn label(&self, g: &FactorGraph) -> String {
        let s = g.stats();
        match *self {
            SamplerSpec::Gibbs(EnergyPath::Generic) => "gibbs".to_string(),
            SamplerSpec::Gibbs(EnergyPath::Specialized) => "gibbs(fast)".to_string(),
            SamplerSpec::MinGibbs { lambda } => {
                format!("min-gibbs λ={:.3}Ψ²", lambda / (s.psi * s.psi))
            }
            SamplerSpec::Local { batch } => format!("local B={batch}"),
            SamplerSpec::Mgpmh { lambda } => {
                format!("mgpmh λ={:.2}L²", lambda / (s.l * s.l))
            }
            SamplerSpec::DoubleMin { lambda1, lambda2 } => format!(
                "doublemin λ₁={:.2}L² λ₂={:.3}Ψ²",
                lambda1 / (s.l * s.l),
                lambda2 / (s.psi * s.psi)
            ),
        }
    }
}

/// Figure 1 workload: the §B Ising model and the sampler lineup
/// (vanilla Gibbs + MIN-Gibbs at increasing batch sizes).
///
/// Note on batch sizes: λ = Ψ² ≈ 1.7·10⁵ makes each MIN-Gibbs iteration
/// *more* expensive than exact Gibbs on this dense model — the paper
/// concedes exactly this in footnote 5 ("we do not expect MIN-Gibbs to
/// be faster than Gibbs for this particular synthetic example"). Figure 1
/// demonstrates the *trajectory* claim instead: the chain is unbiased at
/// any λ and approaches the Gibbs trajectory as λ grows, so we sweep
/// λ ∈ {Ψ²/16, Ψ²/4, Ψ²} (estimator noise δ ≈ Ψ/√λ ∈ {2.6, 1.3, 0.64}).
pub fn fig1_workload() -> (DenseModel, Vec<SamplerSpec>) {
    let m = models::paper_ising();
    let p2 = {
        let psi = m.graph.stats().psi;
        psi * psi
    };
    let specs = vec![
        SamplerSpec::Gibbs(EnergyPath::Specialized),
        SamplerSpec::MinGibbs { lambda: p2 / 16.0 },
        SamplerSpec::MinGibbs { lambda: p2 / 4.0 },
        SamplerSpec::MinGibbs { lambda: p2 },
    ];
    (m, specs)
}

/// Figure 2(a) workload: the §B Ising model, Local Minibatch Gibbs at
/// B ∈ {⅛Δ, ¼Δ, ½Δ} plus the Gibbs reference.
pub fn fig2a_workload() -> (DenseModel, Vec<SamplerSpec>) {
    let m = models::paper_ising();
    let delta = m.graph.stats().delta;
    let specs = vec![
        SamplerSpec::Gibbs(EnergyPath::Specialized),
        SamplerSpec::Local { batch: delta / 8 },
        SamplerSpec::Local { batch: delta / 4 },
        SamplerSpec::Local { batch: delta / 2 },
    ];
    (m, specs)
}

/// Figure 2(b) workload: the §B Potts model, MGPMH at λ ∈ {L², 2L², 4L²}
/// plus the Gibbs reference (paper evaluates three multiples of L²).
pub fn fig2b_workload() -> (DenseModel, Vec<SamplerSpec>) {
    let m = models::paper_potts();
    let l = m.graph.stats().l;
    let specs = vec![
        SamplerSpec::Gibbs(EnergyPath::Specialized),
        SamplerSpec::Mgpmh { lambda: l * l },
        SamplerSpec::Mgpmh { lambda: 2.0 * l * l },
        SamplerSpec::Mgpmh { lambda: 4.0 * l * l },
    ];
    (m, specs)
}

/// Figure 2(c) workload: the §B Potts model, DoubleMIN-Gibbs with
/// λ₁ = L² and second batch sizes λ₂ ∈ {Ψ²/4, Ψ²/2, Ψ²} (the paper
/// adjusts λ₂ "to multiples of Ψ²"), plus MGPMH and Gibbs references.
/// Expected shape: as λ₂ grows DoubleMIN approaches the MGPMH/Gibbs
/// trajectory (Theorem 6).
pub fn fig2c_workload() -> (DenseModel, Vec<SamplerSpec>) {
    let m = models::paper_potts();
    let s = m.graph.stats().clone();
    let (l2, p2) = (s.l * s.l, s.psi * s.psi);
    let specs = vec![
        SamplerSpec::Gibbs(EnergyPath::Specialized),
        SamplerSpec::Mgpmh { lambda: l2 },
        SamplerSpec::DoubleMin { lambda1: l2, lambda2: p2 / 4.0 },
        SamplerSpec::DoubleMin { lambda1: l2, lambda2: p2 / 2.0 },
        SamplerSpec::DoubleMin { lambda1: l2, lambda2: p2 },
    ];
    (m, specs)
}

/// Table-1 sweep sizes: Δ = n − 1 doubles each step. Returns (n values, D).
pub fn table1_sweep() -> (Vec<usize>, u16) {
    (vec![50, 100, 200, 400, 800, 1600], 10)
}

/// Table-1 sweep A — the "many low-energy factors" regime (fixed Ψ = 8,
/// L = 2Ψ/n): Gibbs cost grows O(DΔ) while MIN-Gibbs O(DΨ²) and
/// DoubleMIN O(DL² + Ψ²) stay flat. Each minibatched algorithm gets the
/// paper's O(1)-penalty setting (λ = Ψ², λ₁ = L², λ₂ = Ψ²).
pub fn table1_samplers_fixed_psi(g: &FactorGraph) -> Vec<SamplerSpec> {
    let s = g.stats();
    let (l2, p2) = (s.l * s.l, s.psi * s.psi);
    vec![
        SamplerSpec::Gibbs(EnergyPath::Generic),
        SamplerSpec::MinGibbs { lambda: p2 },
        SamplerSpec::DoubleMin { lambda1: l2.max(0.5), lambda2: p2 },
    ]
}

/// Table-1 sweep B — the "large local neighborhoods" regime (fixed L = 2,
/// Ψ = nL/2): Gibbs O(DΔ) vs MGPMH O(DL² + Δ), whose Δ term has no D
/// factor, so the gap widens by ~D as Δ grows.
pub fn table1_samplers_fixed_l(g: &FactorGraph) -> Vec<SamplerSpec> {
    let s = g.stats();
    let l2 = s.l * s.l;
    vec![
        SamplerSpec::Gibbs(EnergyPath::Generic),
        SamplerSpec::Mgpmh { lambda: l2 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_workloads_build() {
        let (m, specs) = fig1_workload();
        assert_eq!(m.graph.n(), 400);
        assert_eq!(specs.len(), 4);
        for spec in &specs {
            let mut smp = spec.build(&m.graph);
            let mut rng = crate::rng::Pcg64::seeded(1);
            let mut state = vec![0u16; m.graph.n()];
            smp.step(&mut state, &mut rng);
            assert!(!spec.label(&m.graph).is_empty());
        }
    }

    #[test]
    fn fig2_workloads_parameters() {
        let (m, specs) = fig2b_workload();
        assert_eq!(m.graph.domain_size(), 10);
        // first non-gibbs spec is λ = L²
        if let SamplerSpec::Mgpmh { lambda } = specs[1] {
            let l = m.graph.stats().l;
            assert!((lambda - l * l).abs() < 1e-9);
        } else {
            panic!("expected mgpmh spec");
        }
        let (_, specs) = fig2c_workload();
        assert!(matches!(specs[2], SamplerSpec::DoubleMin { .. }));
    }

    /// `supports_parallel` must agree with what the built sampler
    /// reports — it's the static (graph-free) view of `is_site_local`,
    /// used by run-spec validation before any sampler exists.
    #[test]
    fn supports_parallel_matches_built_samplers() {
        let g = crate::graph::models::tiny_random(4, 3, 0.8, 2);
        let specs = [
            SamplerSpec::Gibbs(EnergyPath::Generic),
            SamplerSpec::Gibbs(EnergyPath::Specialized),
            SamplerSpec::MinGibbs { lambda: 10.0 },
            SamplerSpec::Local { batch: 2 },
            SamplerSpec::Mgpmh { lambda: 10.0 },
            SamplerSpec::DoubleMin { lambda1: 5.0, lambda2: 20.0 },
        ];
        for spec in specs {
            let sampler = spec.build(&g);
            assert_eq!(
                spec.supports_parallel(),
                sampler.is_site_local(),
                "spec/sampler disagreement for {spec:?}"
            );
        }
    }

    #[test]
    fn table1_sweep_monotone() {
        let (ns, d) = table1_sweep();
        assert!(ns.windows(2).all(|w| w[0] < w[1]));
        assert!(d >= 2);
        // both lineups build against a sweep graph
        let g = crate::graph::models::table1_workload_fixed_psi(ns[0], d, 8.0);
        assert_eq!(table1_samplers_fixed_psi(&g).len(), 3);
        let g = crate::graph::models::table1_workload(ns[0], d, 2.0);
        assert_eq!(table1_samplers_fixed_l(&g).len(), 2);
    }
}
