//! Summary statistics for benchmark samples.

/// Robust summary of a sample of measurements.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (50th percentile).
    pub median: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Median absolute deviation (scaled by 1.4826 for normal consistency).
    pub mad: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
}

/// Compute a [`Summary`]; panics on empty input.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "no samples");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = percentile_sorted(&sorted, 0.5);
    let mut devs: Vec<f64> = sorted.iter().map(|&x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = 1.4826 * percentile_sorted(&devs, 0.5);
    Summary {
        n,
        mean,
        median,
        stddev: var.sqrt(),
        mad,
        min: sorted[0],
        max: sorted[n - 1],
    }
}

/// Linear-interpolated percentile of pre-sorted data, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 22.0).abs() < 1e-12);
        // MAD robust to the outlier
        assert!(s.mad < 3.0, "mad = {}", s.mad);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
        assert!((percentile_sorted(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let s = summarize(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.stddev, 0.0);
    }
}
