//! Table rendering and CSV output for the benchmark harness.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    /// Table title (used as CSV filename stem and markdown heading).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of stringified cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text/markdown table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                widths[c] = widths[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for c in 0..ncol {
                let _ = write!(line, " {:w$} |", cells[c], w = widths[c]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Write as CSV to `dir/<title>.csv` (title slugified).
    pub fn write_csv(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = dir.join(format!("{slug}.csv"));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }
}

/// A collection of tables making up one benchmark run's output.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Tables in presentation order.
    pub tables: Vec<Table>,
}

impl Report {
    /// New empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a table.
    pub fn push(&mut self, t: Table) {
        self.tables.push(t);
    }

    /// Print all tables to stdout and write CSVs under `dir`.
    pub fn emit(&self, dir: &Path) -> std::io::Result<()> {
        for t in &self.tables {
            println!("{}", t.render());
            let path = t.write_csv(dir)?;
            println!("(csv: {})\n", path.display());
        }
        Ok(())
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_seconds(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["alg", "cost"]);
        t.push_row(vec!["gibbs".into(), "1.0".into()]);
        t.push_row(vec!["mgpmh-long-name".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.lines().count() >= 4);
        // all data lines same width
        let widths: Vec<usize> = r.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn rejects_bad_row() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("mbgibbs_test_csv");
        let mut t = Table::new("My Table 1", &["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        let path = t.write_csv(&dir).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "a,b\n1,2\n");
        assert!(path.file_name().unwrap().to_str().unwrap().starts_with("my_table_1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn human_times() {
        assert!(fmt_seconds(3e-9).contains("ns"));
        assert!(fmt_seconds(3e-6).contains("µs"));
        assert!(fmt_seconds(3e-3).contains("ms"));
        assert!(fmt_seconds(3.0).contains(" s"));
    }
}
