//! Measurement loop: warmup, batched timing, per-iteration costs.

use std::time::Instant;

use super::stats::{summarize, Summary};

/// How a benchmark runs.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup_iters: u64,
    /// Iterations per timed batch.
    pub batch_iters: u64,
    /// Number of timed batches (= number of samples in the summary).
    pub batches: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 1_000,
            batch_iters: 10_000,
            batches: 20,
        }
    }
}

impl BenchConfig {
    /// A quick profile for expensive benchmarks.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 200,
            batch_iters: 2_000,
            batches: 8,
        }
    }
}

/// Benchmark a per-iteration closure; returns per-iteration seconds.
///
/// `f` is called once per iteration with the iteration index; batching
/// amortizes timer overhead.
pub fn bench_iter<F: FnMut(u64)>(cfg: &BenchConfig, mut f: F) -> Summary {
    for i in 0..cfg.warmup_iters {
        f(i);
    }
    let mut samples = Vec::with_capacity(cfg.batches);
    let mut iter = cfg.warmup_iters;
    for _ in 0..cfg.batches {
        let start = Instant::now();
        for _ in 0..cfg.batch_iters {
            f(iter);
            iter += 1;
        }
        let dt = start.elapsed().as_secs_f64();
        samples.push(dt / cfg.batch_iters as f64);
    }
    summarize(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig {
            warmup_iters: 10,
            batch_iters: 1000,
            batches: 5,
        };
        let mut acc = 0u64;
        let s = bench_iter(&cfg, |i| {
            acc = acc.wrapping_add(i).rotate_left(7);
        });
        assert_eq!(s.n, 5);
        assert!(s.median > 0.0);
        assert!(acc != 0); // keep the work observable
    }

    #[test]
    fn iteration_indices_continue_across_batches() {
        let cfg = BenchConfig {
            warmup_iters: 3,
            batch_iters: 10,
            batches: 2,
        };
        let mut max_seen = 0;
        bench_iter(&cfg, |i| max_seen = max_seen.max(i));
        assert_eq!(max_seen, 3 + 20 - 1);
    }
}
