//! Figure regeneration: run a sampler lineup on a model and emit the
//! paper's convergence trajectories as one table (iteration × sampler).

use std::path::Path;

use crate::coordinator::{run_chains, RunOptions, RunSpec};
use crate::graph::models::DenseModel;

use super::report::Table;
use super::workload::SamplerSpec;

/// Parameters for one figure run.
#[derive(Clone, Copy, Debug)]
pub struct FigureParams {
    /// Iterations per sampler (paper: 10⁶).
    pub iters: u64,
    /// Checkpoint cadence for the error trajectory.
    pub record_every: u64,
    /// Master seed.
    pub seed: u64,
}

impl Default for FigureParams {
    fn default() -> Self {
        Self {
            iters: 1_000_000,
            record_every: 10_000,
            seed: 42,
        }
    }
}

impl FigureParams {
    /// A fast smoke profile (CI-sized).
    pub fn quick() -> Self {
        Self {
            iters: 50_000,
            record_every: 2_000,
            seed: 42,
        }
    }
}

/// Run every sampler in `specs` on `model` and return the trajectory table
/// (`iteration`, one error column per sampler) plus a summary table.
pub fn run_figure(
    title: &str,
    model: &DenseModel,
    specs: &[SamplerSpec],
    params: &FigureParams,
) -> (Table, Table) {
    let g = &model.graph;
    let mut columns: Vec<(String, Vec<(u64, f64)>)> = Vec::new();
    let mut summary = Table::new(
        &format!("{title} summary"),
        &[
            "sampler",
            "final_l2_error",
            "evals_per_iter",
            "steps_per_sec",
            "acceptance",
        ],
    );
    for spec in specs {
        let run = RunSpec::builder(*spec)
            .iters(params.iters)
            .record_every(params.record_every)
            .seed(params.seed)
            .build()
            .expect("figure run spec is statically valid");
        let report = run_chains(g, &run, &RunOptions::default());
        let chain = &report.chains[0];
        summary.push_row(vec![
            spec.label(g),
            format!("{:.5}", chain.final_error),
            format!("{:.1}", report.evals_per_iter),
            format!("{:.0}", report.steps_per_sec),
            format!("{:.3}", chain.acceptance),
        ]);
        columns.push((spec.label(g), chain.trajectory.clone()));
    }

    // Assemble the trajectory table on the shared checkpoint grid.
    let mut headers = vec!["iteration".to_string()];
    headers.extend(columns.iter().map(|(l, _)| l.clone()));
    let mut traj = Table::new(
        title,
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let rows = columns.iter().map(|(_, t)| t.len()).min().unwrap_or(0);
    for r in 0..rows {
        let iter = columns[0].1[r].0;
        let mut cells = vec![iter.to_string()];
        for (_, t) in &columns {
            cells.push(format!("{:.6}", t[r].1));
        }
        traj.push_row(cells);
    }
    (traj, summary)
}

/// Run a figure and emit both tables to stdout + CSV under `out`.
pub fn emit_figure(
    title: &str,
    model: &DenseModel,
    specs: &[SamplerSpec],
    params: &FigureParams,
    out: &Path,
) -> std::io::Result<()> {
    let (traj, summary) = run_figure(title, model, specs, params);
    println!("{}", summary.render());
    summary.write_csv(out)?;
    let path = traj.write_csv(out)?;
    println!("(trajectories: {})", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::samplers::EnergyPath;

    #[test]
    fn figure_tables_have_shared_grid() {
        // Tiny stand-in model so the test is fast; the real figures use
        // the paper models via the workload module.
        let m = models::potts_rbf(3, 10, 1.0, 1.5);
        let specs = [
            SamplerSpec::Gibbs(EnergyPath::Specialized),
            SamplerSpec::Mgpmh { lambda: 4.0 },
        ];
        let params = FigureParams {
            iters: 2_000,
            record_every: 500,
            seed: 1,
        };
        let (traj, summary) = run_figure("test fig", &m, &specs, &params);
        assert_eq!(traj.headers.len(), 3);
        assert!(traj.rows.len() >= 4);
        assert_eq!(summary.rows.len(), 2);
        // Errors must be finite and decreasing-ish from the degenerate
        // all-zeros start (first checkpoint > last checkpoint).
        let first: f64 = traj.rows[0][1].parse().unwrap();
        let last: f64 = traj.rows.last().unwrap()[1].parse().unwrap();
        assert!(first >= last, "error should shrink: {first} -> {last}");
    }
}
