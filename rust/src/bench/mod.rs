//! Benchmark harness (criterion is not in the offline dependency set, so
//! the harness is first-party; `cargo bench` targets call into it with
//! `harness = false`).
//!
//! Pieces: [`timer`] measures; [`stats`] summarizes (median/MAD/CI);
//! [`workload`] builds the paper's models and sweeps; [`report`] renders
//! aligned tables and CSV files under `bench_out/`.

pub mod figures;
pub mod report;
pub mod stats;
pub mod timer;
pub mod workload;

pub use report::{Report, Table};
pub use stats::{summarize, Summary};
pub use timer::{bench_iter, BenchConfig};
