//! Stub of the `xla-rs` PJRT binding surface that `mbgibbs::runtime`
//! compiles against. Every entry point that would touch PJRT returns
//! [`Error::Unavailable`] at runtime; the type/shape of the API matches
//! the real binding so `runtime/{executor,backend}.rs` compile unchanged.
//!
//! Why a stub: the offline toolchain has no XLA/PJRT shared library to
//! link. The native samplers (the paper-reproduction path) never touch
//! this crate; only `mbgibbs check-artifacts` and the opt-in
//! `--xla` bench rows do, and those report the unavailability error
//! cleanly. Swap this path dependency for the real `xla` crate to light
//! the backend up — no `mbgibbs` source change required.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub was invoked where the real binding is required.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT binding not compiled into this build (stub crate); \
             vendor the real xla-rs binding to enable the dense backend"
        )
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// A PJRT client handle (stub).
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Always fails in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }

    /// Upload a host tensor. Always fails in the stub.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable)
    }
}

/// An HLO module proto parsed from text (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable)
    }
}

/// An XLA computation (stub).
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with device buffers. Always fails in the stub.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// A device-resident buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Fetch to a host literal. Always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// A host literal (stub).
pub struct Literal(());

impl Literal {
    /// Extract element 0 of a tuple literal. Always fails in the stub.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    /// Convert to a typed vector. Always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let msg = Error::Unavailable.to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}
