//! Minimal API-compatible subset of the `anyhow` crate for the offline
//! dependency set. Covers exactly what this workspace uses: [`Error`],
//! [`Result`], [`anyhow!`], [`bail!`], and the [`Context`] extension
//! trait for `Result` and `Option`.
//!
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion powering `?`.

use std::fmt;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    /// Context chain: `chain[0]` is the outermost message.
    chain: Vec<String>,
}

impl Error {
    /// Create from a single message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole context chain, like real anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the source chain as context lines.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Alias matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Attach a context message to the error branch.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message to the error branch.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_int(s: &str) -> Result<u64> {
        Ok(s.parse::<u64>()?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_int("42").unwrap(), 42);
        assert!(parse_int("nope").is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Error = parse_int("x").context("reading config").unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("reading config: "), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag:?}");
            }
            Err(anyhow!("fell through {}", 7))
        }
        assert!(f(true).unwrap_err().to_string().contains("true"));
        assert!(f(false).unwrap_err().to_string().contains('7'));
    }
}
