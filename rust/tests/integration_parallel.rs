//! Integration: the chromatic parallel sweep engine through the public
//! coordinator surface — worker-count invariance, marginal parity, and
//! bit-exact checkpoint/resume of parallel runs.
//!
//! CI runs this suite twice with `MBGIBBS_TEST_WORKERS` ∈ {1, 4}; the
//! determinism contract (one RNG stream per site) says every assertion
//! must hold identically at both settings.

use std::path::PathBuf;

use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::coordinator::{run_chains, RunOptions, RunSpec};
use mbgibbs::graph::models;
use mbgibbs::samplers::EnergyPath;

/// Worker count under test: the CI matrix exports
/// `MBGIBBS_TEST_WORKERS`; locally the default is 4.
fn ci_workers() -> usize {
    std::env::var("MBGIBBS_TEST_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mbgibbs_ip_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Plain Gibbs: the same graph and seed must produce bit-for-bit
/// identical states and trajectories at workers = 1 and workers = N —
/// per-site RNG streams make the schedule and every conditional draw
/// independent of how sites are sharded over threads.
#[test]
fn gibbs_states_bit_exact_across_worker_counts() {
    let g = models::ising_multipartite(4, 8, 1.5); // n = 32, 4 color classes
    let n = g.n() as u64;
    let mk = |w: usize| {
        RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(n * 40)
            .seed(71)
            .record_every(n * 5)
            .workers(w)
            .build()
            .unwrap()
    };
    let serial = run_chains(&g, &mk(1), &RunOptions::default());
    let wide = run_chains(&g, &mk(ci_workers()), &RunOptions::default());
    assert_eq!(
        serial.chains[0].final_state, wide.chains[0].final_state,
        "worker count changed the Gibbs chain"
    );
    assert_eq!(
        serial.chains[0].trajectory, wide.chains[0].trajectory,
        "worker count changed the recorded marginal-error trajectory"
    );
    assert_eq!(serial.chains[0].factor_evals, wide.chains[0].factor_evals);
}

/// The minibatched site-local samplers (Local, MGPMH) ride the same
/// contract: identical empirical marginals — asserted through the
/// recorded error trajectory and the final error — for any worker count.
#[test]
fn minibatch_marginals_identical_across_worker_counts() {
    let g = models::ising_multipartite(3, 8, 1.5); // n = 24, Δ = 16
    let n = g.n() as u64;
    let lineup = [
        SamplerSpec::Local { batch: 8 },
        SamplerSpec::Mgpmh { lambda: 6.0 },
    ];
    for spec in lineup {
        let mk = |w: usize| {
            RunSpec::builder(spec)
                .iters(n * 30)
                .seed(72)
                .record_every(n * 5)
                .workers(w)
                .build()
                .unwrap()
        };
        let serial = run_chains(&g, &mk(1), &RunOptions::default());
        let wide = run_chains(&g, &mk(ci_workers()), &RunOptions::default());
        assert_eq!(
            serial.chains[0].trajectory, wide.chains[0].trajectory,
            "{spec:?}: marginal trajectory depends on worker count"
        );
        assert_eq!(serial.chains[0].final_error, wide.chains[0].final_error);
        assert_eq!(serial.chains[0].final_state, wide.chains[0].final_state);
    }
}

/// Interrupt + resume of a parallel run replays the exact same chain as
/// the uninterrupted one: v2 checkpoints persist every per-site stream
/// position, and parallel checkpoints land on sweep boundaries so the
/// systematic schedule concatenates seamlessly.
#[test]
fn parallel_resume_is_bit_exact() {
    let g = models::ising_multipartite(3, 6, 1.5); // n = 18
    let n = g.n() as u64;
    let dir = tmpdir("resume");
    let w = ci_workers();

    let uninterrupted = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
        .iters(n * 12)
        .seed(73)
        .record_every(n * 3)
        .workers(w)
        .build()
        .unwrap();
    let full = run_chains(&g, &uninterrupted, &RunOptions::default());

    let first_leg = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
        .iters(n * 6)
        .seed(73)
        .record_every(n * 3)
        .workers(w)
        .checkpoint_dir(dir.clone())
        .checkpoint_every(n * 6)
        .build()
        .unwrap();
    run_chains(&g, &first_leg, &RunOptions::default());
    assert!(dir.join("chain0.ckpt").exists());

    let second_leg = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
        .iters(n * 12)
        .seed(73)
        .record_every(n * 3)
        .workers(w)
        .checkpoint_dir(dir.clone())
        .resume(true)
        .build()
        .unwrap();
    let resumed = run_chains(&g, &second_leg, &RunOptions::default());

    assert_eq!(
        resumed.chains[0].steps_executed,
        n * 6,
        "resume should pick up at the checkpointed sweep"
    );
    assert_eq!(
        full.chains[0].final_state, resumed.chains[0].final_state,
        "resumed parallel chain diverged from the uninterrupted run"
    );
    assert_eq!(full.chains[0].factor_evals, resumed.chains[0].factor_evals);
    std::fs::remove_dir_all(&dir).ok();
}

/// Parallel runs feed the same observability surfaces as serial ones,
/// plus the engine's own `parallel_*` families.
#[test]
fn parallel_metrics_reach_the_report_snapshot() {
    let g = models::ising_multipartite(3, 6, 1.5);
    let n = g.n() as u64;
    let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
        .iters(n * 10)
        .seed(74)
        .record_every(n * 5)
        .workers(ci_workers())
        .build()
        .unwrap();
    let report = run_chains(&g, &spec, &RunOptions::default());
    let snap = &report.metrics;
    assert_eq!(
        snap.counter("parallel_sweeps_total{chain=\"0\"}"),
        Some(10)
    );
    assert_eq!(
        snap.counter("sampler_steps_total{chain=\"0\",sampler=\"gibbs\"}"),
        Some(n * 10)
    );
    let barrier = snap
        .histogram("parallel_color_barrier_ns{chain=\"0\"}")
        .expect("barrier latency histogram registered");
    assert!(barrier.count > 0);
}
