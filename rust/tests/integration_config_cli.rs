//! Integration: config file → model → sampler → coordinator → CSV output,
//! plus CLI round trips.

use std::path::PathBuf;

use mbgibbs::cli;
use mbgibbs::config::ExperimentConfig;
use mbgibbs::coordinator::{run_chains, Checkpoint, RunOptions, RunSpec};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mbgibbs_it_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn config_file_to_run() {
    let dir = tmpdir("cfg");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        r#"
[model]
type = "potts_rbf"
grid_n = 5
d = 10
beta = 4.6
gamma = 1.5

[sampler]
algorithm = "doublemin"
lambda_scale = 1.0
lambda2 = 500.0

[run]
iters = 20000
chains = 2
seed = 3
record_every = 2000
"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::load(&cfg_path).unwrap();
    let (g, dense) = cfg.build_model().unwrap();
    assert_eq!(g.n(), 25);
    assert!(dense.is_some());
    let spec = cfg.sampler_spec(&g).unwrap();
    let run = RunSpec::builder(spec)
        .iters(cfg.run.iters)
        .chains(cfg.run.chains)
        .seed(cfg.run.seed)
        .record_every(cfg.run.record_every)
        .control(cfg.control.to_policy().unwrap())
        .build()
        .unwrap();
    let report = run_chains(&g, &run, &RunOptions::default());
    assert_eq!(report.chains.len(), 2);
    for c in &report.chains {
        assert!(c.final_error.is_finite());
        assert!(!c.trajectory.is_empty());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_sample_command_end_to_end() {
    let dir = tmpdir("cli");
    let cfg_path = dir.join("exp.toml");
    let out_dir = dir.join("out");
    std::fs::write(
        &cfg_path,
        format!(
            r#"
[model]
type = "ising_rbf"
grid_n = 4
beta = 1.0
d = 2

[sampler]
algorithm = "local"
lambda = 4

[run]
iters = 5000
chains = 1
seed = 1
record_every = 1000
output_dir = "{}"
"#,
            out_dir.display()
        ),
    )
    .unwrap();
    cli::run(vec![
        "sample".to_string(),
        "--config".to_string(),
        cfg_path.to_str().unwrap().to_string(),
    ])
    .unwrap();
    let csv = out_dir.join("sample_run.csv");
    assert!(csv.exists(), "CSV not written to {}", csv.display());
    let content = std::fs::read_to_string(csv).unwrap();
    assert!(content.lines().count() >= 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_validate_runs_quick() {
    let dir = tmpdir("validate");
    cli::run(vec![
        "validate".to_string(),
        "--quick".to_string(),
        "--out".to_string(),
        dir.to_str().unwrap().to_string(),
    ])
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_resume_matches_state() {
    // Save a checkpoint mid-run, reload it, confirm state round-trips.
    let dir = tmpdir("ckpt");
    let g = mbgibbs::graph::models::tiny_random(4, 3, 0.8, 12);
    use mbgibbs::rng::Pcg64;
    use mbgibbs::samplers::{EnergyPath, GibbsSampler, Sampler};
    let mut rng = Pcg64::seeded(5);
    let mut sampler = GibbsSampler::new(&g, EnergyPath::Specialized);
    let mut state = vec![0u16; 4];
    for _ in 0..1000 {
        sampler.step(&mut state, &mut rng);
    }
    let ckpt = Checkpoint {
        iter: 1000,
        seed: 5,
        chain: 0,
        factor_evals: 3000,
        accepted: 0,
        proposed: 0,
        rng: Some(rng.state_parts()),
        hyperparams: sampler.hyperparams(),
        aux_energy: sampler.aux_energy(),
        state: state.clone(),
    };
    let path = dir.join("chain0.ckpt");
    ckpt.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.state, state);
    assert_eq!(loaded.iter, 1000);
    assert_eq!(loaded.rng, Some(rng.state_parts()));
    std::fs::remove_dir_all(&dir).ok();
}
