//! Integration: numeric validation of the paper's theorems on exact
//! transition matrices (the DESIGN.md "Thm 2/4/6 (extra)" experiment).

use mbgibbs::analysis::{
    exact_distribution, gibbs_transition_matrix, mgpmh_transition_matrix,
    spectral_gap_reversible, transition, StateSpace,
};
use mbgibbs::graph::models;
use mbgibbs::rng::Pcg64;
use mbgibbs::samplers::{MgpmhSampler, Sampler};

/// Theorem 3: MGPMH's exact transition matrix is reversible wrt π for a
/// range of λ, on several random models.
#[test]
fn theorem3_reversibility_sweep() {
    for seed in 0..4u64 {
        let g = models::tiny_random(3, 2, 0.8, 300 + seed);
        let pi = exact_distribution(&g);
        for &lambda in &[0.5f64, 2.0, 8.0] {
            let t = mgpmh_transition_matrix(&g, lambda);
            let rev = transition::reversibility_violation(&t, &pi);
            let sta = transition::stationarity_violation(&t, &pi);
            assert!(
                rev < 1e-8 && sta < 1e-8,
                "seed {seed} λ {lambda}: rev {rev} sta {sta}"
            );
        }
    }
}

/// Theorem 4: γ̄ ≥ exp(−L²/λ)·γ across models and batch sizes.
#[test]
fn theorem4_spectral_gap_bound() {
    for seed in 0..4u64 {
        let g = models::tiny_random(3, 2, 0.7, 400 + seed);
        let s = g.stats().clone();
        let pi = exact_distribution(&g);
        let gamma = spectral_gap_reversible(&gibbs_transition_matrix(&g), &pi);
        for &scale in &[0.5f64, 1.0, 2.0] {
            let lambda = (s.l * s.l * scale).max(0.3);
            let gamma_mb =
                spectral_gap_reversible(&mgpmh_transition_matrix(&g, lambda), &pi);
            let bound = (-s.l * s.l / lambda).exp() * gamma;
            assert!(
                gamma_mb >= bound - 1e-9,
                "seed {seed} λ {lambda}: γ̄ {gamma_mb} < bound {bound}"
            );
        }
    }
}

/// Theorem 4's qualitative content: the MGPMH gap approaches the Gibbs gap
/// monotonically as λ grows — at the empirical rate 1 − Θ(L/√λ).
#[test]
fn mgpmh_gap_approaches_gibbs() {
    let g = models::tiny_random(3, 2, 0.9, 77);
    let s = g.stats().clone();
    let pi = exact_distribution(&g);
    let gamma = spectral_gap_reversible(&gibbs_transition_matrix(&g), &pi);
    let lams = [0.5f64, 2.0, 10.0, 40.0, 160.0];
    let gaps: Vec<f64> = lams
        .iter()
        .map(|&l| spectral_gap_reversible(&mgpmh_transition_matrix(&g, l), &pi))
        .collect();
    for pair in gaps.windows(2) {
        assert!(pair[1] >= pair[0] - 1e-6, "gaps not improving: {gaps:?}");
    }
    // Convergence rate: the deficit 1 − γ̄/γ scales like λ^{−1/2}
    // (see the Theorem-4 discrepancy note in EXPERIMENTS.md): the λ=40
    // and λ=160 deficits must shrink by ≈ √4 = 2.
    let d40 = 1.0 - gaps[3] / gamma;
    let d160 = 1.0 - gaps[4] / gamma;
    let shrink = d40 / d160;
    assert!(
        (1.5..3.0).contains(&shrink),
        "deficit scaling {shrink} (want ≈ 2): {gaps:?} vs γ = {gamma}"
    );
    // And the gap is within 1 − 1.5·L/√λ of Gibbs (the corrected-form
    // bound our EXPERIMENTS.md discrepancy analysis suggests).
    assert!(
        gaps[4] / gamma >= 1.0 - 1.5 * s.l / 160f64.sqrt(),
        "λ=160 ratio {} below corrected bound",
        gaps[4] / gamma
    );
}

/// DISCREPANCY REGRESSION (see EXPERIMENTS.md §Discrepancies): the
/// *literal* Theorem-4 bound γ̄ ≥ exp(−L²/λ)·γ FAILS for large λ on this
/// model — our exact transition matrix (validated against the sampler by
/// Monte Carlo above) gives a ratio below exp(−L²/λ). The proof's step
/// `max(a·u, a·v) = a·max(u,v)` needs a ≥ 0, but a = s_φL/(λM_φ) − 1 is
/// −1 whenever s_φ = 0, so the true convergence is Θ(L/√λ), not O(L²/λ).
/// The bound *does* hold in the regime the paper recommends (λ ≈ L²,
/// where it is loose); this test pins the large-λ violation so we notice
/// if our implementation ever changes.
#[test]
fn theorem4_literal_bound_fails_at_large_lambda() {
    let g = models::tiny_random(3, 2, 0.9, 77);
    let s = g.stats().clone();
    let pi = exact_distribution(&g);
    let gamma = spectral_gap_reversible(&gibbs_transition_matrix(&g), &pi);
    let lambda = 160.0;
    let gap = spectral_gap_reversible(&mgpmh_transition_matrix(&g, lambda), &pi);
    let ratio = gap / gamma;
    let paper_bound = (-s.l * s.l / lambda).exp();
    assert!(
        ratio < paper_bound,
        "expected the literal Theorem-4 bound to fail here (ratio {ratio}, \
         bound {paper_bound}) — did the implementation change?"
    );
}

/// Exact-vs-empirical transition frequencies: simulate MGPMH and compare
/// observed transition counts from a fixed state against the exact matrix
/// row — end-to-end consistency of sampler and analysis implementations.
#[test]
fn mgpmh_empirical_matches_exact_matrix() {
    let g = models::tiny_random(3, 2, 0.6, 88);
    let lambda = 2.0;
    let t = mgpmh_transition_matrix(&g, lambda);
    let space = StateSpace::for_graph(&g);
    let x0 = vec![0u16, 1u16, 0u16];
    let row = &t[space.index(&x0)];

    let mut rng = Pcg64::seeded(99);
    let trials = 400_000;
    let mut counts = vec![0u64; space.len()];
    let mut sampler = MgpmhSampler::new(&g, lambda);
    for _ in 0..trials {
        let mut state = x0.clone();
        sampler.step(&mut state, &mut rng);
        counts[space.index(&state)] += 1;
    }
    for (idx, (&c, &p)) in counts.iter().zip(row.iter()).enumerate() {
        let f = c as f64 / trials as f64;
        assert!(
            (f - p).abs() < 0.01,
            "state {idx}: empirical {f} vs exact {p}"
        );
    }
}
