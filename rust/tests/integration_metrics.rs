//! Integration: the observability stack end to end — sampler counters
//! through the coordinator's metrics hub, checkpoint/resume counter
//! continuity, and the CLI's `--metrics-out` / `metrics` surfaces.

use std::path::PathBuf;
use std::sync::Arc;

use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::cli;
use mbgibbs::coordinator::{run_chains, RunOptions, RunSpec};
use mbgibbs::graph::models;
use mbgibbs::metrics::{expose, MetricsHub};
use mbgibbs::samplers::EnergyPath;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mbgibbs_im_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Satellite regression: on a complete graph every variable has degree
/// n − 1, so specialized plain Gibbs costs exactly (n − 1) factor
/// evaluations per iteration — in both the chain report and the hub.
#[test]
fn gibbs_factor_evals_are_degree_times_iters() {
    let (n, iters) = (12usize, 2_000u64);
    let g = models::table1_workload(n, 3, 2.0); // complete graph, Δ = n − 1
    let run = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
        .iters(iters)
        .seed(17)
        .record_every(500)
        .build()
        .unwrap();
    let hub = Arc::new(MetricsHub::new());
    let report = run_chains(&g, &run, &RunOptions::with_hub(hub.clone()));

    let want = (n as u64 - 1) * iters;
    assert_eq!(report.chains[0].factor_evals, want);
    let snap = hub.snapshot();
    assert_eq!(
        snap.counter("sampler_factor_evals_total{chain=\"0\",sampler=\"gibbs\"}"),
        Some(want)
    );
    assert_eq!(snap.counter_family_sum("sampler_steps_total"), iters);
}

/// Checkpoint write → resume round trip: the resumed run CONTINUES the
/// metric counters from the saved totals rather than restarting at zero.
#[test]
fn resume_round_trip_continues_counters() {
    let dir = tmpdir("resume");
    let (n, d) = (10usize, 3u16);
    let g = models::table1_workload(n, d, 2.0);

    let leg = |iters: u64, resume: bool| {
        RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
            .iters(iters)
            .seed(23)
            .record_every(100)
            .checkpoint_dir(dir.clone())
            .checkpoint_every(200)
            .resume(resume)
            .build()
            .unwrap()
    };

    // First leg: 400 iterations, leaving a checkpoint at iteration 400.
    let hub1 = Arc::new(MetricsHub::new());
    run_chains(&g, &leg(400, false), &RunOptions::with_hub(hub1.clone()));
    assert!(dir.join("chain0.ckpt").exists());

    // Second leg: resume and extend to 1000 total iterations.
    let hub2 = Arc::new(MetricsHub::new());
    let report = run_chains(&g, &leg(1_000, true), &RunOptions::with_hub(hub2.clone()));

    // Only 600 steps executed in this process...
    assert_eq!(report.chains[0].steps_executed, 600);
    // ...but the counters cover the whole logical run.
    let snap = hub2.snapshot();
    assert_eq!(snap.counter_family_sum("sampler_steps_total"), 1_000);
    assert_eq!(
        snap.counter_family_sum("sampler_factor_evals_total"),
        (n as u64 - 1) * 1_000
    );
    assert_eq!(report.chains[0].factor_evals, (n as u64 - 1) * 1_000);
    std::fs::remove_dir_all(&dir).ok();
}

/// CLI end to end: `sample --metrics-out` writes a parseable JSON
/// snapshot plus a Prometheus sibling, and `metrics --snapshot` pretty
/// prints it back.
#[test]
fn cli_metrics_out_and_metrics_subcommand() {
    let dir = tmpdir("cli");
    let cfg_path = dir.join("exp.toml");
    let out_dir = dir.join("out");
    let snap_path = dir.join("metrics.json");
    std::fs::write(
        &cfg_path,
        format!(
            r#"
[model]
type = "potts_random"
grid_n = 4
d = 3
degree = 4
seed = 7

[sampler]
algorithm = "min-gibbs"
lambda = 60.0

[run]
iters = 3000
chains = 1
seed = 5
record_every = 1000
output_dir = "{}"
"#,
            out_dir.display()
        ),
    )
    .unwrap();
    cli::run(vec![
        "sample".to_string(),
        "--config".to_string(),
        cfg_path.to_str().unwrap().to_string(),
        "--metrics-out".to_string(),
        snap_path.to_str().unwrap().to_string(),
    ])
    .unwrap();

    // JSON snapshot parses back and carries the per-sampler counters,
    // the estimator's minibatch-size histogram, and step latencies.
    let text = std::fs::read_to_string(&snap_path).unwrap();
    let snap = expose::from_json(&text).unwrap();
    assert!(snap.counter_family_sum("sampler_steps_total") == 3_000);
    assert!(snap.counter_family_sum("sampler_factor_evals_total") > 0);
    let mb = snap
        .histogram("sampler_minibatch_global_size{chain=\"0\",sampler=\"min-gibbs\"}")
        .expect("minibatch histogram present");
    assert!(mb.count > 0);
    let lat = snap
        .histogram("chain_step_latency_ns{chain=\"0\"}")
        .expect("latency histogram present");
    assert!(lat.count > 0);
    assert!(lat.p50 <= lat.p95 && lat.p95 <= lat.p99);

    // Prometheus sibling has the right shape.
    let prom = std::fs::read_to_string(snap_path.with_extension("prom")).unwrap();
    assert!(prom.contains("# TYPE sampler_steps_total counter"));
    assert!(prom.contains("chain_step_latency_ns_bucket"));
    assert!(prom.contains("le=\"+Inf\""));

    // The pretty-printer runs on the saved file.
    cli::run(vec![
        "metrics".to_string(),
        "--snapshot".to_string(),
        snap_path.to_str().unwrap().to_string(),
    ])
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// `--metrics-every` without `--metrics-out` is rejected up front.
#[test]
fn metrics_every_requires_metrics_out() {
    let dir = tmpdir("flushargs");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(&cfg_path, "[run]\niters = 10\n").unwrap();
    let err = cli::run(vec![
        "sample".to_string(),
        "--config".to_string(),
        cfg_path.to_str().unwrap().to_string(),
        "--metrics-every".to_string(),
        "1".to_string(),
    ])
    .unwrap_err();
    assert!(err.to_string().contains("--metrics-out"));
    std::fs::remove_dir_all(&dir).ok();
}
