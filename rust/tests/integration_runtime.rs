//! Integration: the AOT artifact path (L1 Pallas → L2 JAX → HLO text →
//! PJRT → L3 Rust) against the native factor-graph implementation.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! message) when the artifacts directory is absent so `cargo test` works
//! in a fresh checkout.

use std::path::PathBuf;

use mbgibbs::graph::models;
use mbgibbs::rng::{Pcg64, Rng};
use mbgibbs::runtime::{backend::parity_report, ArtifactStore, XlaDenseBackend};

fn store() -> Option<ArtifactStore> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ArtifactStore::open(&dir).expect("manifest parses"))
}

#[test]
fn manifest_covers_all_expected_kernels() {
    let Some(store) = store() else { return };
    let names = store.names();
    for want in [
        "potts_cond_energies",
        "ising_cond_energies",
        "potts_weighted_cond_energies",
        "minibatch_estimate",
        "potts_factor_values",
        "potts_total_energy",
        "ising_total_energy",
    ] {
        assert!(names.iter().any(|n| n == want), "missing {want}: {names:?}");
    }
}

#[test]
fn xla_conditional_energies_drive_correct_gibbs_update() {
    // Use the XLA conditional-energy table to compute a Gibbs conditional
    // distribution and compare with the native one — the actual quantity
    // a sampler would consume.
    let Some(store) = store() else { return };
    let model = models::paper_potts();
    let backend = XlaDenseBackend::new(&store, &model).unwrap();
    let g = &model.graph;
    let d = g.domain_size() as usize;
    let mut rng = Pcg64::seeded(31);
    let mut state: Vec<u16> = (0..g.n()).map(|_| rng.index(d) as u16).collect();

    let table = backend.cond_energies_all(&state).unwrap();
    let mut native = vec![0.0f64; d];
    for &i in &[0usize, 57, 200, 399] {
        g.cond_energies_fast(&mut state, i, &mut native);
        // softmax both, compare distributions
        let xla_row: Vec<f64> = (0..d).map(|u| table[i * d + u] as f64).collect();
        let soft = |e: &[f64]| -> Vec<f64> {
            let m = e.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let w: Vec<f64> = e.iter().map(|&x| (x - m).exp()).collect();
            let z: f64 = w.iter().sum();
            w.into_iter().map(|x| x / z).collect()
        };
        let px = soft(&xla_row);
        let pn = soft(&native);
        for u in 0..d {
            assert!(
                (px[u] - pn[u]).abs() < 1e-4,
                "i={i} u={u}: xla {} native {}",
                px[u],
                pn[u]
            );
        }
    }
}

#[test]
fn full_parity_sweep_both_models() {
    let Some(store) = store() else { return };
    for (name, model) in [
        ("potts", models::paper_potts()),
        ("ising", models::paper_ising()),
    ] {
        let backend = XlaDenseBackend::new(&store, &model).unwrap();
        let worst = parity_report(&backend, &model, 3, 17).unwrap();
        assert!(worst < 2e-3, "{name}: deviation {worst}");
    }
}

#[test]
fn minibatch_estimate_kernel_matches_eq2_semantics() {
    // Feed the compiled Eq. (2) kernel a hand-built sparse weight vector
    // and compare with the closed-form sum.
    let Some(store) = store() else { return };
    let exec = mbgibbs::runtime::XlaExecutor::new().unwrap();
    let kernel = exec.load(&store, "minibatch_estimate").unwrap();
    let m = 160_000; // n² for the 20×20 models
    let mut phi = vec![0.0f32; m];
    let mut s = vec![0.0f32; m];
    let mut coef = vec![0.0f32; m];
    // three sampled factors
    let picks = [(3usize, 2.0f32, 0.5f32, 4.0f32), (77, 1.0, 0.25, 8.0), (12345, 3.0, 0.9, 1.5)];
    let mut want = 0.0f64;
    for &(idx, sv, phiv, coefv) in &picks {
        phi[idx] = phiv;
        s[idx] = sv;
        coef[idx] = coefv;
        want += sv as f64 * (1.0 + coefv as f64 * phiv as f64).ln();
    }
    let pb = exec.upload(&phi, &[m]).unwrap();
    let sb = exec.upload(&s, &[m]).unwrap();
    let cb = exec.upload(&coef, &[m]).unwrap();
    let out = kernel.run_f32(&[&pb, &sb, &cb]).unwrap();
    assert!(
        (out[0] as f64 - want).abs() < 1e-4,
        "kernel {} vs closed form {want}",
        out[0]
    );
}
