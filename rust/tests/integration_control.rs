//! Integration: the adaptive controller end to end — λ recovery from a
//! deliberately bad setting on a degree-1000 Ising model, plateau
//! detection, and checkpointed tuned hyperparameters.

use std::sync::Arc;

use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::control::ControlPolicy;
use mbgibbs::coordinator::{run_chains, Checkpoint, RunOptions, RunSpec};
use mbgibbs::graph::models;
use mbgibbs::metrics::MetricsHub;
use mbgibbs::samplers::EnergyPath;

/// The headline acceptance test: on a degree-1000 Ising model (complete
/// graph, L = 2 so the paper's recipe is λ ≈ L² = 4), start MGPMH from a
/// deliberately bad λ = 200 with `--adapt --target-accept 0.7`. The
/// controller must steer the acceptance rate into the target band within
/// the first 20% of iterations, and the adaptive run must finish with
/// fewer total factor evaluations than the fixed bad-λ run.
#[test]
fn adaptive_mgpmh_recovers_from_bad_lambda_on_degree_1000_ising() {
    let g = models::table1_workload(1001, 2, 2.0); // complete graph, Δ = 1000
    let iters = 20_000u64;
    let bad_lambda = 200.0; // 50× the L² recipe

    let fixed = RunSpec::builder(SamplerSpec::Mgpmh { lambda: bad_lambda })
        .iters(iters)
        .seed(31)
        .record_every(5_000)
        .build()
        .unwrap();
    let fixed_report = run_chains(&g, &fixed, &RunOptions::default());
    let fixed_evals = fixed_report.chains[0].factor_evals;

    let adaptive = RunSpec::builder(SamplerSpec::Mgpmh { lambda: bad_lambda })
        .iters(iters)
        .seed(31)
        .record_every(5_000)
        .control(ControlPolicy::target_acceptance(0.7).with_adapt_every(250))
        .build()
        .unwrap();
    let hub = Arc::new(MetricsHub::new());
    let adaptive_report = run_chains(&g, &adaptive, &RunOptions::with_hub(hub.clone()));
    let snap = hub.snapshot();

    // The controller actually adjusted something...
    let adjustments = snap
        .counter("controller_adjustments_total{chain=\"0\"}")
        .expect("adjustments counter registered");
    assert!(adjustments > 0, "controller never adjusted λ");

    // ...the windowed acceptance entered the target band within the
    // first 20% of iterations...
    let settled = snap
        .gauge("controller_settled_iter{chain=\"0\"}")
        .expect("settled gauge registered");
    assert!(
        settled > 0.0 && settled <= iters as f64 * 0.2,
        "acceptance should settle within the first 20% of iterations, settled at {settled}"
    );

    // ...λ ended far below the bad start, visible both as the controller
    // gauge and the sampler's own gauge...
    let lam = snap
        .gauge("controller_lambda{chain=\"0\"}")
        .expect("λ gauge registered");
    assert!(lam < bad_lambda / 2.0, "λ barely moved: {lam}");
    assert_eq!(
        snap.gauge("sampler_lambda{chain=\"0\",sampler=\"mgpmh\"}"),
        Some(lam),
        "sampler gauge must track the retuned λ"
    );
    assert!(
        snap.gauge("controller_evals_per_ess{chain=\"0\"}").unwrap() > 0.0,
        "figure-of-merit gauge missing"
    );

    // ...and the tuned run did strictly less total work.
    let adaptive_evals = adaptive_report.chains[0].factor_evals;
    assert!(
        adaptive_evals < fixed_evals,
        "adaptive run should cost fewer factor evals: {adaptive_evals} vs {fixed_evals}"
    );

    // The chain still mixes: final error comparable to the fixed run.
    assert!(adaptive_report.chains[0].final_error.is_finite());
}

/// Plateau detection: once the error trajectory flattens, the controller
/// freezes (plateau gauge set) and writes an early checkpoint even
/// though no periodic checkpoint cadence is configured.
#[test]
fn plateau_freezes_and_writes_early_checkpoint() {
    let dir = std::env::temp_dir().join(format!(
        "mbgibbs_ic_plateau_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let g = models::tiny_random(4, 3, 0.8, 21);
    let iters = 30_000u64;
    let spec = RunSpec::builder(SamplerSpec::Mgpmh { lambda: 50.0 })
        .iters(iters)
        .seed(33)
        .record_every(200)
        .control(ControlPolicy::target_acceptance(0.7).with_adapt_every(500))
        .checkpoint_dir(dir.clone())
        .build()
        .unwrap();
    let hub = Arc::new(MetricsHub::new());
    run_chains(&g, &spec, &RunOptions::with_hub(hub.clone()));

    assert_eq!(
        hub.snapshot().gauge("controller_plateau{chain=\"0\"}"),
        Some(1.0),
        "tiny fast-mixing model must plateau within {iters} iterations"
    );
    let ckpt = Checkpoint::load(&dir.join("chain0.ckpt"))
        .expect("plateau must have written an early checkpoint");
    assert!(
        ckpt.iter < iters,
        "plateau checkpoint should predate the end of the run"
    );
    assert!(ckpt.rng.is_some());
    assert!(ckpt.hyperparams.lambda.is_some());
    std::fs::remove_dir_all(&dir).ok();
}

/// The control surface is a no-op for samplers without knobs: running
/// plain Gibbs under an adaptive policy must not adjust anything (and
/// must not crash).
#[test]
fn gibbs_under_adaptive_policy_is_untouched() {
    let g = models::tiny_random(4, 2, 0.5, 22);
    let spec = RunSpec::builder(SamplerSpec::Gibbs(EnergyPath::Specialized))
        .iters(5_000)
        .record_every(5_000)
        .control(ControlPolicy::target_acceptance(0.7).with_adapt_every(500))
        .build()
        .unwrap();
    let hub = Arc::new(MetricsHub::new());
    let report = run_chains(&g, &spec, &RunOptions::with_hub(hub.clone()));
    assert_eq!(report.chains[0].acceptance, 1.0);
    assert_eq!(
        hub.snapshot().counter("controller_adjustments_total{chain=\"0\"}"),
        Some(0),
        "nothing to tune on exact Gibbs"
    );
}
