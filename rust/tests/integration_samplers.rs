//! Integration: samplers × coordinator × analysis on real model sizes.

use mbgibbs::analysis::diagnostics;
use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::coordinator::{run_chains, RunOptions, RunSpec};
use mbgibbs::coordinator::{EnergyTraceSink, SampleSink};
use mbgibbs::graph::models;
use mbgibbs::rng::Pcg64;
use mbgibbs::samplers::{EnergyPath, GibbsSampler, MgpmhSampler, Sampler};

/// On the paper's Potts model every sampler's running-marginal error must
/// fall well below the unmixed-start value within 50k iterations.
#[test]
fn paper_potts_error_decreases_all_samplers() {
    let model = models::paper_potts();
    let s = model.graph.stats().clone();
    let specs = vec![
        SamplerSpec::Gibbs(EnergyPath::Specialized),
        SamplerSpec::Local { batch: s.delta / 4 },
        SamplerSpec::Mgpmh { lambda: s.l * s.l },
    ];
    for spec in specs {
        let run = RunSpec::builder(spec)
            .iters(50_000)
            .record_every(5_000)
            .build()
            .unwrap();
        let report = run_chains(&model.graph, &run, &RunOptions::default());
        let c = &report.chains[0];
        let start = c.trajectory.first().unwrap().1;
        let end = c.final_error;
        assert!(
            end < start * 0.5,
            "{}: error {start} -> {end}",
            spec.label(&model.graph)
        );
    }
}

/// Multi-chain agreement: 4 chains × Gibbs on the paper's Ising model must
/// produce a Gelman–Rubin R̂ ≈ 1 on the energy series.
#[test]
fn multichain_energy_rhat_near_one() {
    let model = models::paper_ising();
    let g = &model.graph;
    let mut master = Pcg64::seeded(5);
    let mut chains = Vec::new();
    for k in 0..4u64 {
        let mut rng = master.split(k);
        let mut sampler = GibbsSampler::new(g, EnergyPath::Specialized);
        let mut sink = EnergyTraceSink::new(g, 200);
        let mut state = vec![0u16; g.n()];
        for it in 0..60_000u64 {
            sampler.step(&mut state, &mut rng);
            if it >= 20_000 {
                sink.on_sample(it, &state);
            }
        }
        chains.push(sink.trace);
    }
    let rhat = diagnostics::gelman_rubin(&chains);
    assert!(rhat < 1.2, "rhat = {rhat}");
}

/// MGPMH on the paper Potts model: acceptance at λ = L² must be healthy
/// (the paper's recipe means an O(1) convergence penalty, which implies a
/// non-vanishing acceptance rate).
#[test]
fn mgpmh_acceptance_healthy_on_paper_model() {
    let model = models::paper_potts();
    let s = model.graph.stats().clone();
    let mut sampler = MgpmhSampler::new(&model.graph, s.l * s.l);
    let mut rng = Pcg64::seeded(9);
    let mut state = vec![0u16; model.graph.n()];
    for _ in 0..30_000 {
        sampler.step(&mut state, &mut rng);
    }
    let acc = sampler.acceptance_rate();
    assert!(acc > 0.5, "acceptance = {acc}");
}

/// Energy traces from Gibbs must be stationary around the same level from
/// two very different starts (all-zeros vs random) — a mixing smoke test.
#[test]
fn gibbs_energy_stationary_from_two_starts() {
    let model = models::paper_ising();
    let g = &model.graph;
    let run_from = |init: Vec<u16>, seed: u64| -> f64 {
        let mut rng = Pcg64::seeded(seed);
        let mut sampler = GibbsSampler::new(g, EnergyPath::Specialized);
        let mut state = init;
        for _ in 0..40_000 {
            sampler.step(&mut state, &mut rng);
        }
        // average energy over the tail
        let mut acc = 0.0;
        for _ in 0..10_000 {
            sampler.step(&mut state, &mut rng);
            acc += g.total_energy(&state);
        }
        acc / 10_000.0
    };
    let zeros = run_from(vec![0u16; g.n()], 1);
    let mut rng = Pcg64::seeded(2);
    use mbgibbs::rng::Rng;
    let random: Vec<u16> = (0..g.n()).map(|_| rng.index(2) as u16).collect();
    let other = run_from(random, 3);
    let rel = (zeros - other).abs() / zeros.abs().max(1.0);
    assert!(rel < 0.05, "tail energies differ: {zeros} vs {other}");
}
