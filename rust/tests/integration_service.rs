//! Integration: the persistent inference service end to end — concurrent
//! NDJSON queries over TCP against live chains, marginal parity with a
//! batch replica of the pool discipline, and checkpoint-on-shutdown →
//! bit-exact resume across a full service restart.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use mbgibbs::analysis::MarginalEstimator;
use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::config::JsonValue;
use mbgibbs::coordinator::Checkpoint;
use mbgibbs::graph::models;
use mbgibbs::rng::Pcg64;
use mbgibbs::samplers::EnergyPath;
use mbgibbs::service::{PoolConfig, Service, ServiceOptions};

fn gibbs() -> SamplerSpec {
    SamplerSpec::Gibbs(EnergyPath::Specialized)
}

/// Worker count under test (CI matrix exports `MBGIBBS_TEST_WORKERS`).
fn ci_workers() -> usize {
    std::env::var("MBGIBBS_TEST_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mbgibbs_is_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One NDJSON round trip; panics on transport errors, returns the parsed
/// response.
fn query(addr: SocketAddr, line: &str) -> JsonValue {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    JsonValue::parse(resp.trim()).unwrap()
}

fn assert_ok(resp: &JsonValue) {
    assert_eq!(
        resp.get("ok"),
        Some(&JsonValue::Bool(true)),
        "request failed: {resp:?}"
    );
}

fn dist_of(resp: &JsonValue) -> Vec<f64> {
    resp.get("dist")
        .and_then(|v| v.as_array())
        .expect("response carries a dist array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// Concurrent clients hammer a paused service with marginal, conditional,
/// status, and metrics queries. Marginals must match a hand-rolled batch
/// replica of the pool's per-chain discipline exactly — same master-split
/// streams, same step loop — because the daemon's chains ARE batch chains.
#[test]
fn concurrent_queries_match_batch_estimates() {
    let g = models::tiny_random(4, 3, 0.8, 31);
    let (chains, iters, seed) = (2usize, 4_000u64, 17u64);
    let mut cfg = PoolConfig::new(gibbs(), chains);
    cfg.seed = seed;
    cfg.publish_every = 256;
    cfg.pause_at = iters;
    let svc = Service::start(Arc::new(g.clone()), cfg, &ServiceOptions::default()).unwrap();
    svc.pool().wait_until_paused();
    let addr = svc.local_addr();

    // Batch replica: what `run_chains` would have estimated.
    let mut reference = MarginalEstimator::new(g.n(), g.domain_size() as usize);
    let mut master = Pcg64::seeded(seed);
    for k in 0..chains {
        let mut rng = master.split(k as u64);
        let mut state = vec![0u16; g.n()];
        let mut sampler = gibbs().build(&g);
        sampler.reset(&state, &mut rng);
        for _ in 0..iters {
            sampler.step(&mut state, &mut rng);
            reference.update(&state);
        }
    }

    let mut handles = Vec::new();
    for i in 0..g.n() {
        let expected = reference.marginal(i);
        handles.push(std::thread::spawn(move || {
            let resp = query(addr, &format!("{{\"type\":\"marginal\",\"var\":{i}}}"));
            assert_ok(&resp);
            assert_eq!(
                resp.get("samples").and_then(|v| v.as_f64()),
                Some((iters * chains as u64) as f64)
            );
            let dist = dist_of(&resp);
            assert_eq!(dist.len(), expected.len());
            for (got, want) in dist.iter().zip(&expected) {
                assert!(
                    (got - want).abs() < 1e-12,
                    "marginal({i}) diverged from the batch replica: {got} vs {want}"
                );
            }
        }));
    }
    handles.push(std::thread::spawn(move || {
        let resp = query(
            addr,
            "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":2},\
             \"burn_in\":200,\"samples\":500}",
        );
        assert_ok(&resp);
        let dist = dist_of(&resp);
        let total: f64 = dist.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "conditional dist not normalized");
    }));
    handles.push(std::thread::spawn(move || {
        let resp = query(addr, "{\"type\":\"status\"}");
        assert_ok(&resp);
        assert_eq!(resp.get("chains").and_then(|v| v.as_f64()), Some(2.0));
    }));
    handles.push(std::thread::spawn(move || {
        let resp = query(addr, "{\"type\":\"metrics\"}");
        assert_ok(&resp);
        assert!(resp.get("snapshot").is_some());
    }));
    for h in handles {
        h.join().unwrap();
    }
    svc.shutdown().unwrap();
}

/// Stop a service (flushing checkpoints), start a fresh one with
/// `resume`, run on — the restarted daemon's chain must be bit-identical
/// to a single uninterrupted chain: same state AND same RNG position.
#[test]
fn shutdown_then_restart_resumes_bit_exact() {
    let g = models::tiny_random(4, 3, 0.8, 33);
    let dir = tmpdir("resume");
    let seed = 11u64;
    let mk = |resume: bool, pause: u64| {
        let mut cfg = PoolConfig::new(gibbs(), 1);
        cfg.seed = seed;
        cfg.publish_every = 128;
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_on_shutdown = true;
        cfg.resume = resume;
        cfg.pause_at = pause;
        cfg
    };

    // Leg 1: serve to 1000, shut down over the wire.
    let svc = Service::start(Arc::new(g.clone()), mk(false, 1_000), &ServiceOptions::default())
        .unwrap();
    svc.pool().wait_until_paused();
    let resp = query(svc.local_addr(), "{\"type\":\"shutdown\"}");
    assert_ok(&resp);
    svc.shutdown().unwrap();
    let mid = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
    assert_eq!(mid.iter, 1_000);

    // Leg 2: a fresh service resumes and runs to 2000.
    let svc = Service::start(Arc::new(g.clone()), mk(true, 2_000), &ServiceOptions::default())
        .unwrap();
    svc.pool().wait_until_paused();
    svc.shutdown().unwrap();
    let resumed = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
    assert_eq!(resumed.iter, 2_000);

    // Uninterrupted replica of the same chain, straight to 2000.
    let mut master = Pcg64::seeded(seed);
    let mut rng = master.split(0);
    let mut state = vec![0u16; g.n()];
    let mut sampler = gibbs().build(&g);
    sampler.reset(&state, &mut rng);
    for _ in 0..2_000 {
        sampler.step(&mut state, &mut rng);
    }
    assert_eq!(resumed.state, state, "restart diverged from the uninterrupted chain");
    assert_eq!(
        resumed.rng,
        Some(rng.state_parts()),
        "RNG position diverged across the restart"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The service also fronts parallel (chromatic-sweep) pool chains; the
/// query surface is identical and watermarks land on sweep boundaries.
#[test]
fn parallel_pool_serves_queries() {
    let g = models::ising_multipartite(3, 6, 1.5);
    let n = g.n() as u64;
    let mut cfg = PoolConfig::new(gibbs(), 1);
    cfg.seed = 3;
    cfg.workers = ci_workers();
    cfg.record_every = n * 5;
    cfg.publish_every = n * 10;
    cfg.pause_at = n * 20;
    let svc = Service::start(Arc::new(g.clone()), cfg, &ServiceOptions::default()).unwrap();
    svc.pool().wait_until_paused();

    let resp = query(svc.local_addr(), "{\"type\":\"marginal\",\"var\":0}");
    assert_ok(&resp);
    let dist = dist_of(&resp);
    assert_eq!(dist.len(), g.domain_size() as usize);
    assert!((dist.iter().sum::<f64>() - 1.0).abs() < 1e-9);

    let resp = query(svc.local_addr(), "{\"type\":\"status\"}");
    assert_ok(&resp);
    assert_eq!(
        resp.get("iters")
            .and_then(|v| v.as_array())
            .map(|a| a[0].as_f64().unwrap()),
        Some((n * 20) as f64),
        "parallel watermark should land exactly on the requested sweep boundary"
    );
    svc.shutdown().unwrap();
}
