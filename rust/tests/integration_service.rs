//! Integration: the persistent inference service end to end — concurrent
//! NDJSON queries over TCP against live chains, marginal parity with a
//! batch replica of the pool discipline, and checkpoint-on-shutdown →
//! bit-exact resume across a full service restart.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use mbgibbs::analysis::MarginalEstimator;
use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::config::JsonValue;
use mbgibbs::control::ControlPolicy;
use mbgibbs::coordinator::Checkpoint;
use mbgibbs::graph::models;
use mbgibbs::rng::Pcg64;
use mbgibbs::samplers::EnergyPath;
use mbgibbs::service::{
    PoolConfig, QueryCacheConfig, Service, ServiceOptions, MAX_REQUEST_BYTES,
};

fn gibbs() -> SamplerSpec {
    SamplerSpec::Gibbs(EnergyPath::Specialized)
}

/// Worker count under test (CI matrix exports `MBGIBBS_TEST_WORKERS`).
fn ci_workers() -> usize {
    std::env::var("MBGIBBS_TEST_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mbgibbs_is_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One NDJSON round trip; panics on transport errors, returns the parsed
/// response.
fn query(addr: SocketAddr, line: &str) -> JsonValue {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    JsonValue::parse(resp.trim()).unwrap()
}

fn assert_ok(resp: &JsonValue) {
    assert_eq!(
        resp.get("ok"),
        Some(&JsonValue::Bool(true)),
        "request failed: {resp:?}"
    );
}

fn dist_of(resp: &JsonValue) -> Vec<f64> {
    resp.get("dist")
        .and_then(|v| v.as_array())
        .expect("response carries a dist array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// A persistent NDJSON connection, for multi-request exchanges where the
/// connection itself is under test.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: SocketAddr) -> Conn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        Conn {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn send(&mut self, line: &str) -> JsonValue {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        JsonValue::parse(resp.trim()).unwrap()
    }
}

/// Raw `GET /metrics` scrape over the NDJSON port; returns the full HTTP
/// response (headers + Prometheus text body).
fn scrape(addr: SocketAddr) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n")
        .unwrap();
    writer.flush().unwrap();
    let mut response = String::new();
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l).unwrap() == 0 {
            break;
        }
        response.push_str(&l);
    }
    response
}

/// Value of an (unlabeled) Prometheus counter in a scrape body.
fn scraped_counter(body: &str, name: &str) -> Option<f64> {
    body.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.split_whitespace().next() == Some(name))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// Concurrent clients hammer a paused service with marginal, conditional,
/// status, and metrics queries. Marginals must match a hand-rolled batch
/// replica of the pool's per-chain discipline exactly — same master-split
/// streams, same step loop — because the daemon's chains ARE batch chains.
#[test]
fn concurrent_queries_match_batch_estimates() {
    let g = models::tiny_random(4, 3, 0.8, 31);
    let (chains, iters, seed) = (2usize, 4_000u64, 17u64);
    let mut cfg = PoolConfig::new(gibbs(), chains);
    cfg.seed = seed;
    cfg.publish_every = 256;
    cfg.pause_at = iters;
    let svc = Service::start(Arc::new(g.clone()), cfg, &ServiceOptions::default()).unwrap();
    svc.pool().wait_until_paused();
    let addr = svc.local_addr();

    // Batch replica: what `run_chains` would have estimated.
    let mut reference = MarginalEstimator::new(g.n(), g.domain_size() as usize);
    let mut master = Pcg64::seeded(seed);
    for k in 0..chains {
        let mut rng = master.split(k as u64);
        let mut state = vec![0u16; g.n()];
        let mut sampler = gibbs().build(&g);
        sampler.reset(&state, &mut rng);
        for _ in 0..iters {
            sampler.step(&mut state, &mut rng);
            reference.update(&state);
        }
    }

    let mut handles = Vec::new();
    for i in 0..g.n() {
        let expected = reference.marginal(i);
        handles.push(std::thread::spawn(move || {
            let resp = query(addr, &format!("{{\"type\":\"marginal\",\"var\":{i}}}"));
            assert_ok(&resp);
            assert_eq!(
                resp.get("samples").and_then(|v| v.as_f64()),
                Some((iters * chains as u64) as f64)
            );
            let dist = dist_of(&resp);
            assert_eq!(dist.len(), expected.len());
            for (got, want) in dist.iter().zip(&expected) {
                assert!(
                    (got - want).abs() < 1e-12,
                    "marginal({i}) diverged from the batch replica: {got} vs {want}"
                );
            }
        }));
    }
    handles.push(std::thread::spawn(move || {
        let resp = query(
            addr,
            "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":2},\
             \"burn_in\":200,\"samples\":500}",
        );
        assert_ok(&resp);
        let dist = dist_of(&resp);
        let total: f64 = dist.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "conditional dist not normalized");
    }));
    handles.push(std::thread::spawn(move || {
        let resp = query(addr, "{\"type\":\"status\"}");
        assert_ok(&resp);
        assert_eq!(resp.get("chains").and_then(|v| v.as_f64()), Some(2.0));
    }));
    handles.push(std::thread::spawn(move || {
        let resp = query(addr, "{\"type\":\"metrics\"}");
        assert_ok(&resp);
        assert!(resp.get("snapshot").is_some());
    }));
    for h in handles {
        h.join().unwrap();
    }
    svc.shutdown().unwrap();
}

/// Stop a service (flushing checkpoints), start a fresh one with
/// `resume`, run on — the restarted daemon's chain must be bit-identical
/// to a single uninterrupted chain: same state AND same RNG position.
#[test]
fn shutdown_then_restart_resumes_bit_exact() {
    let g = models::tiny_random(4, 3, 0.8, 33);
    let dir = tmpdir("resume");
    let seed = 11u64;
    let mk = |resume: bool, pause: u64| {
        let mut cfg = PoolConfig::new(gibbs(), 1);
        cfg.seed = seed;
        cfg.publish_every = 128;
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_on_shutdown = true;
        cfg.resume = resume;
        cfg.pause_at = pause;
        cfg
    };

    // Leg 1: serve to 1000, shut down over the wire.
    let svc = Service::start(Arc::new(g.clone()), mk(false, 1_000), &ServiceOptions::default())
        .unwrap();
    svc.pool().wait_until_paused();
    let resp = query(svc.local_addr(), "{\"type\":\"shutdown\"}");
    assert_ok(&resp);
    svc.shutdown().unwrap();
    let mid = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
    assert_eq!(mid.iter, 1_000);

    // Leg 2: a fresh service resumes and runs to 2000.
    let svc = Service::start(Arc::new(g.clone()), mk(true, 2_000), &ServiceOptions::default())
        .unwrap();
    svc.pool().wait_until_paused();
    svc.shutdown().unwrap();
    let resumed = Checkpoint::load(&dir.join("chain0.ckpt")).unwrap();
    assert_eq!(resumed.iter, 2_000);

    // Uninterrupted replica of the same chain, straight to 2000.
    let mut master = Pcg64::seeded(seed);
    let mut rng = master.split(0);
    let mut state = vec![0u16; g.n()];
    let mut sampler = gibbs().build(&g);
    sampler.reset(&state, &mut rng);
    for _ in 0..2_000 {
        sampler.step(&mut state, &mut rng);
    }
    assert_eq!(resumed.state, state, "restart diverged from the uninterrupted chain");
    assert_eq!(
        resumed.rng,
        Some(rng.state_parts()),
        "RNG position diverged across the restart"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The service also fronts parallel (chromatic-sweep) pool chains; the
/// query surface is identical and watermarks land on sweep boundaries.
#[test]
fn parallel_pool_serves_queries() {
    let g = models::ising_multipartite(3, 6, 1.5);
    let n = g.n() as u64;
    let mut cfg = PoolConfig::new(gibbs(), 1);
    cfg.seed = 3;
    cfg.workers = ci_workers();
    cfg.record_every = n * 5;
    cfg.publish_every = n * 10;
    cfg.pause_at = n * 20;
    let svc = Service::start(Arc::new(g.clone()), cfg, &ServiceOptions::default()).unwrap();
    svc.pool().wait_until_paused();

    let resp = query(svc.local_addr(), "{\"type\":\"marginal\",\"var\":0}");
    assert_ok(&resp);
    let dist = dist_of(&resp);
    assert_eq!(dist.len(), g.domain_size() as usize);
    assert!((dist.iter().sum::<f64>() - 1.0).abs() < 1e-9);

    let resp = query(svc.local_addr(), "{\"type\":\"status\"}");
    assert_ok(&resp);
    assert_eq!(
        resp.get("iters")
            .and_then(|v| v.as_array())
            .map(|a| a[0].as_f64().unwrap()),
        Some((n * 20) as f64),
        "parallel watermark should land exactly on the requested sweep boundary"
    );
    svc.shutdown().unwrap();
}

/// Adaptive serving, serial path: the controller retunes λ online, the
/// tuned value rides the shutdown checkpoint, and a restarted adaptive
/// service is bit-identical to an uninterrupted adaptive run — state,
/// RNG position, and hyperparameters. The pause watermarks are multiples
/// of `adapt_every`, so checkpoints land exactly on review boundaries
/// (the documented resume-exactness condition).
#[test]
fn adaptive_serial_resume_is_bit_exact() {
    let g = models::tiny_random(4, 3, 0.8, 26);
    let lambda0 = 400.0;
    let mk = |dir: &PathBuf, resume: bool, pause: u64| {
        let mut cfg = PoolConfig::new(SamplerSpec::Mgpmh { lambda: lambda0 }, 1);
        cfg.seed = 13;
        cfg.publish_every = 256;
        // Trajectory stays empty in-window so the plateau detector
        // never freezes the controller mid-test.
        cfg.record_every = 1_000_000;
        cfg.adapt = ControlPolicy::target_acceptance(0.7).with_adapt_every(500);
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_on_shutdown = true;
        cfg.resume = resume;
        cfg.pause_at = pause;
        cfg
    };
    let run = |dir: &PathBuf, resume: bool, pause: u64| {
        let svc =
            Service::start(Arc::new(g.clone()), mk(dir, resume, pause), &ServiceOptions::default())
                .unwrap();
        svc.pool().wait_until_paused();
        svc.shutdown().unwrap();
        Checkpoint::load(&dir.join("chain0.ckpt")).unwrap()
    };

    // Interrupted: 0 → 2000, restart, → 4000.
    let dir = tmpdir("adapt_serial");
    let mid = run(&dir, false, 2_000);
    assert_eq!(mid.iter, 2_000);
    let mid_lambda = mid.hyperparams.lambda.expect("MGPMH checkpoint carries lambda");
    assert!(
        mid_lambda < lambda0,
        "controller should have shrunk the oversized λ by the first shutdown, got {mid_lambda}"
    );
    let resumed = run(&dir, true, 4_000);
    assert_eq!(resumed.iter, 4_000);

    // Uninterrupted replica in a fresh directory.
    let dir2 = tmpdir("adapt_serial_ref");
    let straight = run(&dir2, false, 4_000);

    assert_eq!(resumed.state, straight.state, "adaptive restart diverged in state");
    assert_eq!(resumed.rng, straight.rng, "adaptive restart diverged in RNG position");
    assert_eq!(
        resumed.hyperparams, straight.hyperparams,
        "tuned hyperparameters diverged across the restart"
    );
    assert_eq!(resumed.factor_evals, straight.factor_evals);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// Adaptive serving, chromatic-parallel path: reviews fire at sweep
/// barriers, so shutdown → restart stays bit-exact AND the tuned
/// trajectory is invariant under the worker count. Watermarks are
/// multiples of both the sweep length n and `adapt_every`.
#[test]
fn adaptive_parallel_resume_is_bit_exact_and_worker_invariant() {
    let g = models::ising_multipartite(3, 6, 1.5);
    let n = g.n() as u64;
    let lambda0 = 400.0;
    let mk = |dir: &PathBuf, workers: usize, resume: bool, pause: u64| {
        let mut cfg = PoolConfig::new(SamplerSpec::Mgpmh { lambda: lambda0 }, 1);
        cfg.seed = 29;
        cfg.workers = workers;
        cfg.publish_every = n * 10;
        cfg.record_every = 1_000_000;
        cfg.adapt = ControlPolicy::target_acceptance(0.7).with_adapt_every(n * 5);
        cfg.checkpoint_dir = Some(dir.clone());
        cfg.checkpoint_on_shutdown = true;
        cfg.resume = resume;
        cfg.pause_at = pause;
        cfg
    };
    let run = |dir: &PathBuf, workers: usize, resume: bool, pause: u64| {
        let svc = Service::start(
            Arc::new(g.clone()),
            mk(dir, workers, resume, pause),
            &ServiceOptions::default(),
        )
        .unwrap();
        svc.pool().wait_until_paused();
        svc.shutdown().unwrap();
        Checkpoint::load(&dir.join("chain0.ckpt")).unwrap()
    };

    // Interrupted at n*20 (whole sweeps, a review boundary), resumed to n*40.
    let dir = tmpdir("adapt_par");
    let mid = run(&dir, ci_workers(), false, n * 20);
    assert_eq!(mid.iter, n * 20);
    let mid_lambda = mid.hyperparams.lambda.expect("MGPMH checkpoint carries lambda");
    assert!(
        mid_lambda < lambda0,
        "controller should have shrunk the oversized λ by the first shutdown, got {mid_lambda}"
    );
    let resumed = run(&dir, ci_workers(), true, n * 40);
    assert_eq!(resumed.iter, n * 40);

    // Uninterrupted replica, same worker count.
    let dir2 = tmpdir("adapt_par_ref");
    let straight = run(&dir2, ci_workers(), false, n * 40);
    assert_eq!(resumed.state, straight.state, "parallel adaptive restart diverged in state");
    assert_eq!(resumed.rng, straight.rng);
    assert_eq!(
        resumed.site_rngs, straight.site_rngs,
        "per-site RNG positions diverged across the restart"
    );
    assert_eq!(
        resumed.hyperparams, straight.hyperparams,
        "tuned hyperparameters diverged across the restart"
    );

    // Worker-count invariance: one worker, uninterrupted, same answer.
    let dir3 = tmpdir("adapt_par_w1");
    let solo = run(&dir3, 1, false, n * 40);
    assert_eq!(
        solo.state, straight.state,
        "adaptive trajectory must be invariant under the worker count"
    );
    assert_eq!(solo.site_rngs, straight.site_rngs);
    assert_eq!(
        solo.hyperparams, straight.hyperparams,
        "tuned λ must not depend on the worker count"
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
    std::fs::remove_dir_all(&dir3).ok();
}

/// N identical concurrent conditional queries trigger exactly one
/// re-burn-in: every client gets the bit-identical marginal, the
/// coalesce/cache counters account for all non-leaders, and the
/// `no_cache` bypass replays the same chain (key-derived RNG) so the
/// unbatched path agrees bit-exactly.
#[test]
fn identical_conditionals_coalesce_over_tcp() {
    let g = models::tiny_random(4, 3, 0.8, 37);
    let mut cfg = PoolConfig::new(gibbs(), 1);
    cfg.seed = 21;
    cfg.publish_every = 256;
    cfg.pause_at = 1_024;
    // A generous TTL keeps the run-count assertions timing-independent
    // even on a heavily loaded test host.
    let opts = ServiceOptions {
        query_cache: QueryCacheConfig {
            enabled: true,
            ttl: Duration::from_secs(120),
            capacity: 64,
        },
        ..ServiceOptions::default()
    };
    let svc = Service::start(Arc::new(g), cfg, &opts).unwrap();
    svc.pool().wait_until_paused();
    let addr = svc.local_addr();

    let line = "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":2},\
                \"burn_in\":300,\"samples\":2000}";
    let clients = 6usize;
    let mut handles = Vec::new();
    for _ in 0..clients {
        handles.push(std::thread::spawn(move || {
            let resp = query(addr, line);
            assert_ok(&resp);
            let source = resp
                .get("source")
                .and_then(|v| v.as_str())
                .expect("conditional responses carry a source")
                .to_string();
            (dist_of(&resp), source)
        }));
    }
    let results: Vec<(Vec<f64>, String)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (dist, source) in &results[1..] {
        assert_eq!(
            dist, &results[0].0,
            "coalesced/cached answers must be bit-identical to the leader's"
        );
        assert!(
            ["sampled", "coalesced", "cached"].contains(&source.as_str()),
            "unexpected source {source:?}"
        );
    }

    let body = scrape(addr);
    assert_eq!(
        scraped_counter(&body, "service_conditional_runs_total"),
        Some(1.0),
        "identical concurrent conditionals must trigger exactly one re-burn-in:\n{body}"
    );
    let coalesced = scraped_counter(&body, "service_conditional_coalesced_total").unwrap_or(0.0);
    let hits = scraped_counter(&body, "service_conditional_cache_hits_total").unwrap_or(0.0);
    assert_eq!(
        coalesced + hits,
        (clients - 1) as f64,
        "every non-leader is either coalesced or cache-served (coalesced = {coalesced}, \
         hits = {hits})"
    );

    // `no_cache` runs its own chain — but the key-derived RNG stream
    // makes the answer bit-equal to the batched path.
    let resp = query(
        addr,
        "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":2},\
         \"burn_in\":300,\"samples\":2000,\"no_cache\":true}",
    );
    assert_ok(&resp);
    assert_eq!(resp.get("source").and_then(|v| v.as_str()), Some("sampled"));
    assert_eq!(
        dist_of(&resp),
        results[0].0,
        "the unbatched path must agree bit-exactly with the coalesced one"
    );
    let body = scrape(addr);
    assert_eq!(
        scraped_counter(&body, "service_conditional_runs_total"),
        Some(2.0),
        "no_cache must run its own chain"
    );
    svc.shutdown().unwrap();
}

/// Malformed input hardening: truncated JSON, unknown types, out-of-range
/// variables and evidence, zero-sample and over-cap budgets, oversized
/// request lines, and mid-request disconnects — every one must produce a
/// structured error (or a clean close) and leave the listener serving
/// subsequent requests.
#[test]
fn malformed_requests_leave_the_listener_serving() {
    let g = models::tiny_random(4, 3, 0.8, 35);
    let mut cfg = PoolConfig::new(gibbs(), 1);
    cfg.seed = 5;
    cfg.publish_every = 128;
    cfg.pause_at = 512;
    let svc = Service::start(Arc::new(g), cfg, &ServiceOptions::default()).unwrap();
    svc.pool().wait_until_paused();
    let addr = svc.local_addr();

    let expect_err = |resp: &JsonValue| -> String {
        assert_eq!(
            resp.get("ok"),
            Some(&JsonValue::Bool(false)),
            "expected a structured error, got {resp:?}"
        );
        resp.get("error")
            .and_then(|v| v.as_str())
            .expect("errors carry an \"error\" string")
            .to_string()
    };

    // One connection survives a parade of bad requests.
    let mut conn = Conn::open(addr);
    assert!(!expect_err(&conn.send("{\"type\":\"stat")).is_empty(), "truncated JSON line");
    assert!(expect_err(&conn.send("{\"type\":\"frobnicate\"}")).contains("unknown request type"));
    assert!(expect_err(&conn.send("{\"type\":\"marginal\",\"var\":99}")).contains("out of range"));
    assert!(expect_err(
        &conn.send("{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"99\":0}}")
    )
    .contains("out of range"));
    assert!(expect_err(
        &conn.send("{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":1},\"samples\":0}")
    )
    .contains("samples"));
    assert!(expect_err(&conn.send(
        "{\"type\":\"conditional\",\"var\":1,\"evidence\":{\"0\":1},\
         \"burn_in\":60000000,\"samples\":1}"
    ))
    .contains("cap"));
    // The same connection still answers a good query.
    assert_ok(&conn.send("{\"type\":\"status\"}"));
    drop(conn);

    // A client that disconnects mid-request doesn't take the listener out.
    {
        let stream = TcpStream::connect(addr).unwrap();
        (&stream).write_all(b"{\"type\":\"margi").unwrap();
        drop(stream);
    }

    // An oversized request line gets a structured error, then the server
    // closes the connection (the line tail can't be resynchronized to).
    // Send exactly cap = MAX_REQUEST_BYTES + 1 bytes with no newline so
    // the server consumes everything we wrote before closing.
    let mut big = Conn::open(addr);
    let payload = vec![b'x'; MAX_REQUEST_BYTES + 1];
    big.writer.write_all(&payload).unwrap();
    big.writer.flush().unwrap();
    let mut resp = String::new();
    big.reader.read_line(&mut resp).unwrap();
    let resp = JsonValue::parse(resp.trim()).unwrap();
    assert!(expect_err(&resp).contains("exceeds"), "oversized line error");
    let mut eof = String::new();
    assert_eq!(
        big.reader.read_line(&mut eof).unwrap(),
        0,
        "an oversized line must close the connection"
    );
    drop(big);

    // Fresh connections keep working after all of the above.
    let resp = query(addr, "{\"type\":\"marginal\",\"var\":0}");
    assert_ok(&resp);
    svc.shutdown().unwrap();
}
