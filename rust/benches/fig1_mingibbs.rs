//! Figure 1 reproduction: MIN-Gibbs marginal-error trajectories vs vanilla
//! Gibbs on the §B Ising model (20×20 RBF, β = 1, L = 2.21, Ψ = 416.1),
//! for batch sizes λ ∈ {¼, ½, 1, 2}·Ψ².
//!
//! Expected shape: every MIN-Gibbs trajectory converges (unbiased chain);
//! larger λ tracks the Gibbs trajectory more closely.
//!
//! Run: `cargo bench --bench fig1_mingibbs [-- --full]`
//! (default 150k iterations; `--full` = the paper's 10⁶)

use mbgibbs::bench::figures::{run_figure, FigureParams};
use mbgibbs::bench::workload::fig1_workload;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let params = if full {
        FigureParams::default()
    } else {
        FigureParams {
            iters: 150_000,
            record_every: 5_000,
            seed: 42,
        }
    };
    let (model, specs) = fig1_workload();
    eprintln!(
        "figure 1: Ising n = {}, Ψ = {:.1}, {} iterations per sampler",
        model.graph.n(),
        model.graph.stats().psi,
        params.iters
    );
    let (traj, summary) = run_figure("figure1 min-gibbs ising", &model, &specs, &params);
    println!("{}", summary.render());
    let out = std::path::Path::new("bench_out");
    summary.write_csv(out).expect("csv");
    let p = traj.write_csv(out).expect("csv");
    println!("(trajectories: {})", p.display());
}
