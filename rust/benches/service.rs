//! Service throughput benchmark: queries/sec against a live daemon on
//! the degree-1000 multipartite Ising model (n = 1250, Δ = 1000) —
//! the start of the service perf trajectory (BENCH_service.json).
//!
//! Four client threads hammer the NDJSON port with marginal queries
//! while the pool free-runs; a separate pass measures status queries.
//! Results land in `bench_out/BENCH_service.json`.
//!
//! Run: `cargo bench --bench service [-- --quick]`

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mbgibbs::bench::workload::SamplerSpec;
use mbgibbs::graph::models;
use mbgibbs::samplers::EnergyPath;
use mbgibbs::service::{PoolConfig, Service, ServiceOptions};

const CLIENTS: usize = 4;

/// One persistent client connection issuing `line` in a loop until
/// `stop`; counts completed round trips.
fn client_loop(addr: SocketAddr, line: String, stop: Arc<AtomicBool>, done: Arc<AtomicU64>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("timeout");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    let mut resp = String::new();
    while !stop.load(Ordering::Relaxed) {
        writer.write_all(line.as_bytes()).expect("write");
        writer.write_all(b"\n").expect("write");
        writer.flush().expect("flush");
        resp.clear();
        reader.read_line(&mut resp).expect("read");
        assert!(resp.contains("\"ok\":true"), "query failed: {resp}");
        done.fetch_add(1, Ordering::Relaxed);
    }
}

/// Measure sustained queries/sec for `line` over `secs` seconds.
fn measure(addr: SocketAddr, line: &str, secs: f64) -> (u64, f64) {
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let (line, stop, done) = (line.to_string(), stop.clone(), done.clone());
            std::thread::spawn(move || client_loop(addr, line, stop, done))
        })
        .collect();
    let t0 = Instant::now();
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().expect("client thread");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    (total, total as f64 / elapsed)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let secs = if quick { 0.5 } else { 1.5 };

    // The acceptance workload: degree-1000 multipartite Ising.
    let g = models::ising_multipartite(5, 250, 2.0);
    let n = g.n();
    let mut cfg = PoolConfig::new(SamplerSpec::Gibbs(EnergyPath::Specialized), 2);
    cfg.seed = 13;
    cfg.record_every = (n as u64) * 4;
    cfg.publish_every = 4_096;
    let svc = Service::start(Arc::new(g), cfg, &ServiceOptions::default()).expect("service");
    let addr = svc.local_addr();
    // Let the pool publish at least one slice so queries see samples.
    std::thread::sleep(Duration::from_millis(300));

    let (marginal_n, marginal_qps) = measure(addr, "{\"type\":\"marginal\",\"var\":0}", secs);
    let (status_n, status_qps) = measure(addr, "{\"type\":\"status\"}", secs);

    println!(
        "service bench (n = {n}, Δ = 1000, {CLIENTS} clients, 2 chains):\n\
         \x20 marginal: {marginal_n} queries, {marginal_qps:.0} q/s\n\
         \x20 status:   {status_n} queries, {status_qps:.0} q/s"
    );

    let out_dir = std::path::Path::new("bench_out");
    std::fs::create_dir_all(out_dir).expect("bench_out");
    let json = format!(
        "{{\n  \"bench\": \"service\",\n  \"model\": \"ising_multipartite(5, 250, 2.0)\",\n  \
         \"clients\": {CLIENTS},\n  \"chains\": 2,\n  \"seconds_per_pass\": {secs},\n  \
         \"marginal_queries\": {marginal_n},\n  \"marginal_qps\": {marginal_qps:.1},\n  \
         \"status_queries\": {status_n},\n  \"status_qps\": {status_qps:.1}\n}}\n"
    );
    std::fs::write(out_dir.join("BENCH_service.json"), json).expect("write BENCH_service.json");
    println!("wrote bench_out/BENCH_service.json");

    svc.shutdown().expect("shutdown");
}
