//! Hot-path micro-benchmarks: the per-iteration building blocks of every
//! sampler, plus the XLA-backend call overhead. This is the §Perf
//! instrument — EXPERIMENTS.md records its before/after numbers.
//!
//! Run: `cargo bench --bench hotpath [-- --quick] [-- --xla]`

use mbgibbs::bench::report::{fmt_seconds, Table};
use mbgibbs::bench::timer::{bench_iter, BenchConfig};
use mbgibbs::graph::models;
use mbgibbs::metrics::SamplerMetrics;
use mbgibbs::rng::{
    sample_categorical_from_energies, sample_poisson, Pcg64, Rng, SparsePoissonSampler,
};
use mbgibbs::samplers::{
    DenseGibbsSampler, DoubleMinGibbsSampler, EnergyPath, GibbsSampler, MgpmhSampler,
    MinGibbsSampler, PoissonEnergyEstimator, Sampler,
};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let with_xla = args.iter().any(|a| a == "--xla");
    let cfg = if quick {
        BenchConfig {
            warmup_iters: 200,
            batch_iters: 2_000,
            batches: 5,
        }
    } else {
        BenchConfig::default()
    };
    let mut table = Table::new("hotpath", &["op", "median", "ns"]);
    let mut add = |name: &str, median: f64| {
        table.push_row(vec![
            name.to_string(),
            fmt_seconds(median),
            format!("{:.1}", median * 1e9),
        ]);
    };

    let potts = models::paper_potts();
    let g = &potts.graph;
    let stats = g.stats().clone();
    let d = g.domain_size() as usize;
    let mut rng = Pcg64::seeded(1);
    let mut state: Vec<u16> = (0..g.n()).map(|_| rng.index(d) as u16).collect();

    // --- primitive ops ---
    {
        let mut out = vec![0.0f64; d];
        let mut i = 0usize;
        let s = bench_iter(&cfg, |_| {
            g.cond_energies_generic(&mut state, i, &mut out);
            i = (i + 1) % g.n();
        });
        add("cond_energies generic (Δ=399,D=10)", s.median);
        let s = bench_iter(&cfg, |_| {
            g.cond_energies_fast(&mut state, i, &mut out);
            i = (i + 1) % g.n();
        });
        add("cond_energies fast", s.median);
    }
    {
        let s = bench_iter(&cfg, |_| {
            std::hint::black_box(sample_poisson(&mut rng, 25.9));
        });
        add("poisson(λ=25.9)", s.median);
        let s = bench_iter(&cfg, |_| {
            std::hint::black_box(sample_poisson(&mut rng, 2.5));
        });
        add("poisson(λ=2.5)", s.median);
    }
    {
        let rates: Vec<f64> = g.max_energies().to_vec();
        let lambda = stats.l * stats.l;
        let scaled: Vec<f64> = rates.iter().map(|&m| lambda * m / stats.psi).collect();
        let mut sp = SparsePoissonSampler::new(&scaled);
        let s = bench_iter(&cfg, |_| {
            sp.sample_into(&mut rng, |i, c| {
                std::hint::black_box((i, c));
            });
        });
        add("sparse poisson vector (global)", s.median);
    }
    {
        let energies: Vec<f64> = (0..d).map(|u| (u as f64) * 0.3).collect();
        let s = bench_iter(&cfg, |_| {
            std::hint::black_box(sample_categorical_from_energies(&mut rng, &energies));
        });
        add("categorical D=10", s.median);
    }
    {
        let mut est = PoissonEnergyEstimator::new(g, 4_000.0);
        let s = bench_iter(&cfg, |_| {
            std::hint::black_box(est.estimate(g, &state, &mut rng));
        });
        add("eq2 estimator (λ=4000)", s.median);
    }

    // --- full sampler steps on the paper models ---
    {
        let mut s1 = GibbsSampler::new(g, EnergyPath::Generic);
        let s = bench_iter(&cfg, |_| {
            s1.step(&mut state, &mut rng);
        });
        add("step gibbs generic (potts)", s.median);
        let mut s2 = GibbsSampler::new(g, EnergyPath::Specialized);
        let s = bench_iter(&cfg, |_| {
            s2.step(&mut state, &mut rng);
        });
        add("step gibbs fast (potts)", s.median);
        // Same step with metrics attached — the delta is the observability
        // overhead (budget: < 5%; two Relaxed atomic adds per step).
        let mut s2m = GibbsSampler::new(g, EnergyPath::Specialized);
        s2m.attach_metrics(std::sync::Arc::new(SamplerMetrics::detached()));
        let s = bench_iter(&cfg, |_| {
            s2m.step(&mut state, &mut rng);
        });
        add("step gibbs fast + metrics (potts)", s.median);
        let mut s2d = DenseGibbsSampler::new(&potts);
        let s = bench_iter(&cfg, |_| {
            s2d.step(&mut state, &mut rng);
        });
        add("step dense-gibbs (potts)", s.median);
        let mut s3 = MgpmhSampler::new(g, stats.l * stats.l);
        let s = bench_iter(&cfg, |_| {
            s3.step(&mut state, &mut rng);
        });
        add("step mgpmh λ=L² (potts)", s.median);
        let mut s3m = MgpmhSampler::new(g, stats.l * stats.l);
        s3m.attach_metrics(std::sync::Arc::new(SamplerMetrics::detached()));
        let s = bench_iter(&cfg, |_| {
            s3m.step(&mut state, &mut rng);
        });
        add("step mgpmh λ=L² + metrics (potts)", s.median);
        let mut s4 = MinGibbsSampler::new(g, 4_000.0);
        let mincfg = BenchConfig {
            warmup_iters: 10,
            batch_iters: if quick { 20 } else { 100 },
            batches: 5,
        };
        let s = bench_iter(&mincfg, |_| {
            s4.step(&mut state, &mut rng);
        });
        add("step min-gibbs λ=4000 (potts)", s.median);
        let mut s5 = DoubleMinGibbsSampler::new(g, stats.l * stats.l, 4_000.0);
        let dmcfg = BenchConfig {
            warmup_iters: 10,
            batch_iters: if quick { 50 } else { 500 },
            batches: 5,
        };
        let s = bench_iter(&dmcfg, |_| {
            s5.step(&mut state, &mut rng);
        });
        add("step doublemin λ₁=L²,λ₂=4000 (potts)", s.median);
    }

    // --- chromatic parallel sweeps: serial vs 4 workers ---
    // Acceptance row: on the degree-1000 multipartite Ising model
    // (n = 1250, 5 color classes of 250) the 4-worker engine must beat
    // the 1-worker engine by ≥2× in sweep throughput.
    {
        use mbgibbs::bench::workload::SamplerSpec;
        use mbgibbs::metrics::MetricsHub;
        use mbgibbs::runtime::ChromaticSweepEngine;

        let mg = models::ising_multipartite(5, 250, 2.0);
        let sweeps = if quick { 4u64 } else { 20 };
        let iters = sweeps * mg.n() as u64;
        let mut throughput = [0.0f64; 2];
        for (slot, workers) in [(0usize, 1usize), (1, 4)] {
            let hub = MetricsHub::new();
            let m = SamplerMetrics::register(&hub, &[("chain", "bench")]);
            let mut prng = Pcg64::seeded(9);
            let engine = ChromaticSweepEngine::new(
                &mg,
                SamplerSpec::Gibbs(EnergyPath::Specialized),
                workers,
                &mut prng,
                m,
                &hub,
                "bench",
            );
            let mut mstate = vec![0u16; mg.n()];
            let t0 = std::time::Instant::now();
            engine.run(&mut mstate, 0, iters, &mut |_| {});
            let secs = t0.elapsed().as_secs_f64();
            throughput[slot] = iters as f64 / secs;
            add(
                &format!("chromatic sweep gibbs workers={workers} (Δ=1000)"),
                secs / iters as f64,
            );
        }
        eprintln!(
            "chromatic sweep speedup at 4 workers: {:.2}x (target ≥ 2x)",
            throughput[1] / throughput[0]
        );
    }

    // --- XLA backend round-trip (opt-in: PJRT client startup is slow) ---
    if with_xla {
        use mbgibbs::runtime::{ArtifactStore, XlaDenseBackend};
        let store = ArtifactStore::open(std::path::Path::new("artifacts")).expect("artifacts");
        let xcfg = BenchConfig {
            warmup_iters: 3,
            batch_iters: 20,
            batches: 5,
        };
        let pallas = XlaDenseBackend::new_pallas(&store, &potts).expect("backend");
        let s = bench_iter(&xcfg, |_| {
            std::hint::black_box(pallas.cond_energies_all(&state).unwrap());
        });
        add("xla cond_energies_all pallas-interp (400×10)", s.median);
        let dot = XlaDenseBackend::new(&store, &potts).expect("backend");
        let s = bench_iter(&xcfg, |_| {
            std::hint::black_box(dot.cond_energies_all(&state).unwrap());
        });
        add("xla cond_energies_all fused-dot (400×10)", s.median);
        let s = bench_iter(&xcfg, |_| {
            std::hint::black_box(dot.total_energy(&state).unwrap());
        });
        add("xla total_energy fused-dot", s.median);
    }

    println!("{}", table.render());
    table
        .write_csv(std::path::Path::new("bench_out"))
        .expect("csv");
}
