//! Table 1 reproduction: single-iteration computational cost of each
//! algorithm as the maximum degree Δ grows, at parameter settings with an
//! O(1) spectral-gap penalty (λ = Ψ² / L² per the paper's recipe).
//!
//! Paper's complexity table (the shape we must reproduce):
//!   * Gibbs:          O(DΔ)       — grows linearly in Δ
//!   * MIN-Gibbs:      O(DΨ²)      — flat in Δ
//!   * MGPMH:          O(DL² + Δ)  — grows, but ~D× slower than Gibbs
//!   * DoubleMIN:      O(DL² + Ψ²) — flat in Δ
//!
//! Two sweeps isolate the two regimes:
//!   A (fixed Ψ = 8, "many low-energy factors"): Gibbs vs MIN-Gibbs vs
//!     DoubleMIN — minibatched costs must be flat while Gibbs grows.
//!   B (fixed L = 2, "large local neighborhoods"): Gibbs vs MGPMH —
//!     both grow with Δ but MGPMH's Δ term carries no D factor.
//!
//! Run: `cargo bench --bench table1 [-- --quick]`

use mbgibbs::bench::report::{fmt_seconds, Table};
use mbgibbs::bench::timer::{bench_iter, BenchConfig};
use mbgibbs::bench::workload;
use mbgibbs::graph::models;
use mbgibbs::graph::FactorGraph;
use mbgibbs::metrics::{MetricsHub, SamplerMetrics};
use mbgibbs::rng::Pcg64;
use mbgibbs::runtime::ChromaticSweepEngine;
use mbgibbs::samplers::EnergyPath;

fn run_sweep(
    title: &str,
    ns: &[usize],
    build: impl Fn(usize) -> FactorGraph,
    lineup: impl Fn(&FactorGraph) -> Vec<workload::SamplerSpec>,
    cfg: &BenchConfig,
) -> Table {
    let mut table = Table::new(
        title,
        &[
            "n",
            "delta",
            "sampler",
            "median_time",
            "time_ns",
            "evals_per_iter",
        ],
    );
    for &n in ns {
        let g = build(n);
        eprintln!("  n = {n} (Δ = {}) ...", g.stats().delta);
        for spec in lineup(&g) {
            let mut sampler = spec.build(&g);
            let mut rng = Pcg64::seeded(7);
            let mut state = vec![0u16; n];
            sampler.reset(&state, &mut rng);
            let mut evals = 0u64;
            let mut steps = 0u64;
            let summary = bench_iter(cfg, |_| {
                let st = sampler.step(&mut state, &mut rng);
                evals += st.factor_evals;
                steps += 1;
            });
            table.push_row(vec![
                n.to_string(),
                g.stats().delta.to_string(),
                spec.label(&g),
                fmt_seconds(summary.median),
                format!("{:.0}", summary.median * 1e9),
                format!("{:.1}", evals as f64 / steps as f64),
            ]);
        }
    }
    table
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        BenchConfig {
            warmup_iters: 100,
            batch_iters: 500,
            batches: 5,
        }
    } else {
        BenchConfig {
            warmup_iters: 1_000,
            batch_iters: 5_000,
            batches: 12,
        }
    };
    let (mut ns, d) = workload::table1_sweep();
    if quick {
        ns.truncate(4);
    }
    let out = std::path::Path::new("bench_out");

    eprintln!("sweep A: fixed Ψ = 8 (many low-energy factors)");
    let a = run_sweep(
        "table1 sweep A fixed psi",
        &ns,
        |n| models::table1_workload_fixed_psi(n, d, 8.0),
        |g| workload::table1_samplers_fixed_psi(g),
        &cfg,
    );
    println!("{}", a.render());
    a.write_csv(out).expect("csv");

    eprintln!("sweep B: fixed L = 2 (large local neighborhoods)");
    let b = run_sweep(
        "table1 sweep B fixed l",
        &ns,
        |n| models::table1_workload(n, d, 2.0),
        |g| workload::table1_samplers_fixed_l(g),
        &cfg,
    );
    println!("{}", b.render());
    b.write_csv(out).expect("csv");

    // Sweep C: serial vs parallel chromatic sweeps of plain Gibbs on the
    // degree-1000 multipartite Ising model (n = 1250, 5 color classes).
    // Per-site randomness makes the result identical at every worker
    // count, so the only difference between rows is wall-clock.
    eprintln!("sweep C: chromatic parallel sweeps (degree-1000 multipartite Ising)");
    let g = models::ising_multipartite(5, 250, 2.0);
    let mut c = Table::new(
        "table1 sweep C chromatic parallel",
        &["workers", "colors", "ns_per_iter", "iters_per_sec", "speedup_vs_serial"],
    );
    let sweeps = if quick { 4u64 } else { 20 };
    let iters = sweeps * g.n() as u64;
    let mut serial = 0.0f64;
    for workers in [1usize, 4] {
        let hub = MetricsHub::new();
        let m = SamplerMetrics::register(&hub, &[("chain", "bench")]);
        let mut rng = Pcg64::seeded(9);
        let engine = ChromaticSweepEngine::new(
            &g,
            workload::SamplerSpec::Gibbs(EnergyPath::Specialized),
            workers,
            &mut rng,
            m,
            &hub,
            "bench",
        );
        let mut state = vec![0u16; g.n()];
        let t0 = std::time::Instant::now();
        engine.run(&mut state, 0, iters, &mut |_| {});
        let secs = t0.elapsed().as_secs_f64();
        let per_sec = iters as f64 / secs;
        if workers == 1 {
            serial = per_sec;
        }
        c.push_row(vec![
            workers.to_string(),
            g.coloring().num_colors().to_string(),
            format!("{:.0}", secs * 1e9 / iters as f64),
            format!("{:.0}", per_sec),
            format!("{:.2}", per_sec / serial),
        ]);
    }
    println!("{}", c.render());
    c.write_csv(out).expect("csv");

    println!(
        "Expected shape — sweep A: gibbs time grows ~linearly in Δ while\n\
         min-gibbs/doublemin stay flat; sweep B: both grow, but mgpmh's\n\
         slope is ~{d}× (= D) shallower than gibbs's."
    );
}
