//! Figure 2 reproduction — all three panels:
//!   (a) Local Minibatch Gibbs on the §B Ising model, B ∈ {⅛, ¼, ½}·Δ;
//!   (b) MGPMH on the §B Potts model (D = 10, β = 4.6), λ ∈ {1, 2, 4}·L²;
//!   (c) DoubleMIN-Gibbs on the Potts model, λ₁ = L², λ₂ ∈ {1, 2, 4}·Ψ².
//!
//! Expected shape (paper): every variant converges with nearly the same
//! trajectory as vanilla Gibbs, approaching it as batch size increases.
//!
//! Run: `cargo bench --bench fig2_convergence [-- 2a|2b|2c] [-- --full]`

use mbgibbs::bench::figures::{run_figure, FigureParams};
use mbgibbs::bench::workload;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");
    let which: Vec<&str> = ["2a", "2b", "2c"]
        .into_iter()
        .filter(|w| args.iter().any(|a| a == w))
        .collect();
    let which = if which.is_empty() {
        vec!["2a", "2b", "2c"]
    } else {
        which
    };
    let out = std::path::Path::new("bench_out");
    for panel in which {
        let (title, (model, specs)) = match panel {
            "2a" => ("figure2a local minibatch ising", workload::fig2a_workload()),
            "2b" => ("figure2b mgpmh potts", workload::fig2b_workload()),
            "2c" => ("figure2c doublemin potts", workload::fig2c_workload()),
            _ => unreachable!(),
        };
        // 2c's second minibatch is Θ(Ψ²)-sized (≈ 1 ms/step), so its
        // default is shorter; --full restores the paper's 10⁶ everywhere.
        let params = if full {
            FigureParams::default()
        } else {
            FigureParams {
                iters: if panel == "2c" { 60_000 } else { 120_000 },
                record_every: if panel == "2c" { 2_500 } else { 5_000 },
                seed: 42,
            }
        };
        eprintln!("{title}: {} iterations per sampler", params.iters);
        let (traj, summary) = run_figure(title, &model, &specs, &params);
        println!("{}", summary.render());
        summary.write_csv(out).expect("csv");
        let p = traj.write_csv(out).expect("csv");
        println!("(trajectories: {})\n", p.display());
    }
}
